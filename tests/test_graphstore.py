"""Out-of-core GraphStore tests (DESIGN.md §15): mmap bundle round-trip,
chunked-engine equivalence against the in-RAM backend, manifest integrity
hard-errors, the streamed external-memory CSR builder, and the streamed
dataset factory's bit-for-bit equality with its in-RAM twin."""
import json
import os

import numpy as np
import pytest

from repro.core import (Graph, GraphStoreError, GraphStoreIntegrityError,
                        MmapGraphStore, atomic_directory,
                        build_partition_batch, build_store_from_edge_batches,
                        connected_components, connected_components_chunks,
                        evaluate_partition, leiden_fusion, make_arxiv_like,
                        partition_from_spec, quotient_edges, split_components,
                        store_from_graph)
from repro.pipeline.datasets import graph_fingerprint, make_arxiv_like_stream

CHUNK = 5_000      # small enough that the test graphs span several chunks


@pytest.fixture(scope="module")
def ds():
    return make_arxiv_like(n=3_000, seed=3)


@pytest.fixture(scope="module")
def pair(ds, tmp_path_factory):
    root = tmp_path_factory.mktemp("store") / "bundle"
    return ds.graph, store_from_graph(ds.graph, str(root), chunk_arcs=CHUNK)


# ---------------------------------------------------------------------------
# bundle round-trip + protocol equivalence
# ---------------------------------------------------------------------------
def test_store_roundtrips_csr(pair):
    g, s = pair
    assert s.num_chunks > 1                      # the chunking is exercised
    assert s.n == g.n and s.num_arcs == g.num_arcs
    assert s.m == pytest.approx(g.m)
    np.testing.assert_array_equal(np.asarray(s.indptr), g.indptr)
    src, dst, w = g.arcs()
    got_s, got_d, got_w = [], [], []
    prev_stop = 0
    for ch in s.iter_csr_chunks():
        assert ch.row_start == prev_stop         # chunks tile the node range
        prev_stop = ch.row_stop
        assert ch.arc_stop - ch.arc_start == ch.dst.shape[0]
        got_s.append(ch.src); got_d.append(ch.dst); got_w.append(ch.weight)
    assert prev_stop == g.n
    np.testing.assert_array_equal(np.concatenate(got_s), src)
    np.testing.assert_array_equal(np.concatenate(got_d), dst)
    np.testing.assert_array_equal(np.concatenate(got_w), w)
    np.testing.assert_allclose(s.degrees(), g.degrees())


def test_store_arcs_raises(pair):
    """Whole-graph materialization must fail loudly — that is the
    out-of-core contract."""
    _, s = pair
    with pytest.raises(GraphStoreError, match="iter_csr_chunks"):
        s.arcs()


def test_gather_arcs_matches_graph(pair):
    g, s = pair
    rng = np.random.default_rng(0)
    for size in (1, 17, 400):
        nodes = np.unique(rng.integers(0, g.n, size))
        a = g.gather_arcs(nodes)
        b = s.gather_arcs(nodes)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
    empty = s.gather_arcs(np.zeros(0, dtype=np.int64))
    assert all(e.size == 0 for e in empty)


def test_quotient_edges_matches_graph(pair):
    g, s = pair
    labels = np.random.default_rng(1).integers(0, 7, g.n)
    qa, qb = quotient_edges(g, labels), quotient_edges(s, labels)
    assert qa.k == qb.k
    np.testing.assert_array_equal(qa.src, qb.src)
    np.testing.assert_array_equal(qa.dst, qb.dst)
    np.testing.assert_allclose(qa.weight, qb.weight)
    np.testing.assert_allclose(qa.intra, qb.intra)
    np.testing.assert_allclose(qa.node_weight, qb.node_weight)


def test_aggregate_matches_graph(pair):
    g, s = pair
    labels = np.random.default_rng(2).integers(0, 5, g.n)
    ag, as_ = g.aggregate(labels), s.aggregate(labels)
    assert isinstance(as_, Graph)                # coarsened graph is in-RAM
    np.testing.assert_array_equal(ag.indptr, as_.indptr)
    np.testing.assert_array_equal(ag.indices, as_.indices)
    np.testing.assert_allclose(ag.edge_weight, as_.edge_weight)
    np.testing.assert_allclose(ag.self_weight, as_.self_weight)


def test_connected_components_match(pair):
    g, s = pair
    np.testing.assert_array_equal(g.connected_components(),
                                  s.connected_components())
    mask = np.random.default_rng(3).random(g.n) < 0.6
    np.testing.assert_array_equal(g.connected_components(mask),
                                  s.connected_components(mask))
    assert g.num_components() == s.num_components()


def test_connected_components_chunks_equals_array_version():
    rng = np.random.default_rng(4)
    n = 500
    src = rng.integers(0, n, 800)
    dst = rng.integers(0, n, 800)
    want = connected_components(n, src, dst)
    # feed the same edges in 7 chunks
    cuts = np.linspace(0, 800, 8).astype(int)

    def chunks():
        for a, b in zip(cuts[:-1], cuts[1:]):
            yield src[a:b], dst[a:b]
    np.testing.assert_array_equal(
        connected_components_chunks(n, chunks), want)
    mask = rng.random(n) < 0.5
    np.testing.assert_array_equal(
        connected_components_chunks(n, chunks, mask=mask),
        connected_components(n, src, dst, mask=mask))


def test_split_components_matches_graph(pair):
    g, s = pair
    labels = np.random.default_rng(5).integers(0, 4, g.n)
    np.testing.assert_array_equal(split_components(g, labels),
                                  split_components(s, labels))


# ---------------------------------------------------------------------------
# partition -> metrics -> batch on the store
# ---------------------------------------------------------------------------
def test_leiden_fusion_on_store_is_valid_and_matches_quality(pair):
    g, s = pair
    k = 6
    la = leiden_fusion(g, k, seed=0)
    lb = leiden_fusion(s, k, seed=0)
    ra = evaluate_partition(g, la)
    rb = evaluate_partition(s, lb)
    # the paper's guarantees hold out-of-core: connected, no isolated nodes
    assert rb.max_components == 1 and rb.total_isolated == 0
    # and quality is within noise of the in-RAM run on the same graph
    assert rb.edge_cut_pct == pytest.approx(ra.edge_cut_pct, abs=2.0)
    assert rb.node_balance == pytest.approx(ra.node_balance, abs=0.1)


def test_evaluate_partition_matches_graph(pair):
    g, s = pair
    labels = leiden_fusion(g, 6, seed=0)
    ra = evaluate_partition(g, labels).as_dict()
    rb = evaluate_partition(s, labels).as_dict()
    for key, val in ra.items():
        assert rb[key] == pytest.approx(val), key


def test_partition_from_spec_accepts_store(pair):
    _, s = pair
    res = partition_from_spec(s, "leiden_fusion", 4, seed=0)
    assert res.labels.shape == (s.n,)
    assert int(res.labels.max()) + 1 == 4


def test_build_partition_batch_matches_graph(pair):
    g, s = pair
    labels = leiden_fusion(g, 4, seed=0)
    for scheme in ("inner", "repli"):
        ba = build_partition_batch(g, labels, scheme=scheme)
        bb = build_partition_batch(s, labels, scheme=scheme)
        assert ba.n_pad == bb.n_pad and ba.e_pad == bb.e_pad
        for f in ("node_ids", "node_mask", "owned_mask", "edge_src",
                  "edge_dst", "edge_weight", "in_degree"):
            np.testing.assert_array_equal(getattr(ba, f), getattr(bb, f),
                                          err_msg=f"{scheme}:{f}")


# ---------------------------------------------------------------------------
# manifest integrity — hard errors, never silent fallbacks
# ---------------------------------------------------------------------------
def _edit_manifest(root, mutate):
    mpath = os.path.join(root, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    mutate(manifest)
    with open(mpath, "w") as f:
        json.dump(manifest, f)


def test_tampered_manifest_is_a_hard_error(ds, tmp_path):
    root = str(tmp_path / "b")
    store_from_graph(ds.graph, root, chunk_arcs=CHUNK)
    _edit_manifest(root, lambda m: m.__setitem__("n", m["n"] + 1))
    with pytest.raises(GraphStoreIntegrityError, match="fingerprint"):
        MmapGraphStore.load(root)


def test_tampered_data_file_fails_verify(ds, tmp_path):
    root = str(tmp_path / "b")
    store_from_graph(ds.graph, root, chunk_arcs=CHUNK)
    target = os.path.join(root, "chunks", "00000.weights.npy")
    arr = np.load(target)
    arr[0] += 1.0
    np.save(target, arr)
    # plain load only checks the manifest; verify=True re-hashes the files
    MmapGraphStore.load(root)
    with pytest.raises(GraphStoreIntegrityError, match="hash mismatch"):
        MmapGraphStore.load(root, verify=True)


def test_missing_chunk_file_is_an_error(ds, tmp_path):
    root = str(tmp_path / "b")
    store_from_graph(ds.graph, root, chunk_arcs=CHUNK)
    os.unlink(os.path.join(root, "chunks", "00000.indices.npy"))
    with pytest.raises(GraphStoreError, match="missing data file"):
        MmapGraphStore.load(root)


def test_newer_format_version_is_an_error(ds, tmp_path):
    root = str(tmp_path / "b")
    store_from_graph(ds.graph, root, chunk_arcs=CHUNK)

    def bump(m):
        m["version"] = 99
        # keep the fingerprint consistent so the version check is what trips
        from repro.core.graphstore import _fingerprint_from
        m["fingerprint"] = _fingerprint_from(m)
    _edit_manifest(root, bump)
    with pytest.raises(GraphStoreError, match="newer"):
        MmapGraphStore.load(root)


def test_atomic_directory_discards_on_error(tmp_path):
    final = str(tmp_path / "bundle")
    with pytest.raises(RuntimeError, match="boom"):
        with atomic_directory(final) as tmp:
            with open(os.path.join(tmp, "half-written"), "w") as f:
                f.write("x")
            raise RuntimeError("boom")
    assert not os.path.exists(final)
    assert os.listdir(str(tmp_path)) == []       # temp tree cleaned up

    with atomic_directory(final) as tmp:
        with open(os.path.join(tmp, "a"), "w") as f:
            f.write("1")
    with atomic_directory(final) as tmp:         # replace an existing bundle
        with open(os.path.join(tmp, "b"), "w") as f:
            f.write("2")
    assert os.listdir(final) == ["b"]


# ---------------------------------------------------------------------------
# the external-memory builder + streamed dataset factory
# ---------------------------------------------------------------------------
def test_build_store_from_edge_batches_matches_from_edges(tmp_path):
    rng = np.random.default_rng(6)
    n = 2_000
    src = rng.integers(0, n, 6_000)
    dst = rng.integers(0, n, 6_000)
    g = Graph.from_edges(n, src, dst)

    def batches():
        for a in range(0, 6_000, 1_234):
            yield src[a:a + 1_234], dst[a:a + 1_234]
    s = build_store_from_edge_batches(
        str(tmp_path / "b"), n, batches(), est_arcs=12_000, chunk_arcs=CHUNK,
        ensure_connected=False)
    np.testing.assert_array_equal(np.asarray(s.indptr), g.indptr)
    dsts = np.concatenate([ch.dst for ch in s.iter_csr_chunks()])
    ws = np.concatenate([ch.weight for ch in s.iter_csr_chunks()])
    np.testing.assert_array_equal(dsts, g.indices)
    np.testing.assert_array_equal(ws, g.edge_weight)  # dup edges summed


def test_streamed_dataset_is_bit_identical_to_in_ram(tmp_path):
    """The tentpole equivalence: make_arxiv_like_stream mirrors
    make_arxiv_like's rng draws exactly, so CSR, labels, features, and masks
    all come out bit-for-bit equal — only the storage backend differs."""
    ram = make_arxiv_like(n=4_000, seed=5)
    st = make_arxiv_like_stream(out_dir=str(tmp_path / "d"), n=4_000, seed=5,
                                chunk_arcs=CHUNK)
    g, s = ram.graph, st.graph
    assert isinstance(s, MmapGraphStore) and s.num_chunks > 1
    np.testing.assert_array_equal(np.asarray(s.indptr), g.indptr)
    dsts = np.concatenate([ch.dst for ch in s.iter_csr_chunks()])
    np.testing.assert_array_equal(dsts, g.indices)
    np.testing.assert_array_equal(ram.labels, st.labels)
    np.testing.assert_array_equal(ram.features, np.asarray(st.features))
    assert isinstance(st.features, np.memmap)    # features stay on disk
    for m in ("train_mask", "val_mask", "test_mask"):
        np.testing.assert_array_equal(getattr(ram, m), getattr(st, m))


def test_graph_fingerprint_is_backend_invariant(tmp_path):
    """A store and the in-RAM Graph with the same CSR hash identically, so
    they share partition-cache entries (DESIGN.md §15)."""
    ram = make_arxiv_like(n=2_000, seed=5)
    st = make_arxiv_like_stream(out_dir=str(tmp_path / "d"), n=2_000, seed=5)
    assert graph_fingerprint(ram.graph) == graph_fingerprint(st.graph)
    copied = store_from_graph(ram.graph, str(tmp_path / "c"),
                              chunk_arcs=CHUNK)
    assert graph_fingerprint(copied) == graph_fingerprint(ram.graph)
    other = make_arxiv_like(n=2_000, seed=6)
    assert graph_fingerprint(other.graph) != graph_fingerprint(ram.graph)


# ---------------------------------------------------------------------------
# low-memory sequential local training (DESIGN.md §15)
# ---------------------------------------------------------------------------
def test_sequential_local_training_matches_vmap(ds):
    """train_local(sequential=True) — the low_memory pipeline path — must
    produce the same parameters and embeddings as the vmapped step: local
    partitions never interact and the per-epoch dropout keys are shared, so
    the two are the same math in a different loop order."""
    import jax
    from repro.gnn import GNNConfig
    from repro.gnn.train import train_local

    res = partition_from_spec(ds.graph, "leiden_fusion", 4, seed=0)
    batch = build_partition_batch(ds.graph, res.labels, scheme="repli")
    cfg = GNNConfig(feature_dim=ds.features.shape[1], hidden_dim=16,
                    embed_dim=8, num_layers=2, dropout=0.3)
    pv, ev = train_local(ds, batch, cfg, epochs=3, seed=7)
    ps, es = train_local(ds, batch, cfg, epochs=3, seed=7, sequential=True)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a),
                                                np.asarray(b), atol=1e-6),
        pv, ps)
    np.testing.assert_allclose(ev, es, atol=1e-6)


def test_pipeline_low_memory_end_to_end(ds, tmp_path):
    """The pipeline's low_memory flag runs the whole flow (partition ->
    sequential train -> assembly -> eval) and reports the same accuracy as
    the vmapped run at the same seed."""
    from repro.pipeline import Pipeline, PipelineConfig

    common = dict(dataset="arxiv_like", method="leiden_fusion", k=4,
                  mode="local", epochs=3, classifier_epochs=5, hidden_dim=16,
                  embed_dim=8, num_layers=2, cache_dir=None,
                  collect_hlo=False, shard_data_axis=False)
    r_lo = Pipeline(PipelineConfig(low_memory=True, **common)).run(ds)
    r_hi = Pipeline(PipelineConfig(**common)).run(ds)
    assert r_lo.accuracy["test"] == pytest.approx(r_hi.accuracy["test"])
