"""Tests for the vectorized partitioning engine (repro.core.engine).

Three layers:
1. primitive equivalence — quotient_edges / connected_components /
   split_components against brute-force references;
2. CommunityState invariants — the incrementally-merged adjacency must
   stay consistent with a from-scratch quotient after any merge sequence;
3. end-to-end invariants (hypothesis over random connected SBMs) — the
   paper's guarantees survive the vectorized rewrite, deterministically.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CommunityState, Graph, connected_components,
                        evaluate_partition, fuse, karate_club, leiden,
                        leiden_fusion, quotient_edges, split_components)
from repro.core.fusion import community_cuts


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _bfs_components(g: Graph, mask=None) -> np.ndarray:
    """The seed implementation's per-node BFS, kept as the reference."""
    if mask is None:
        mask = np.ones(g.n, dtype=bool)
    comp = np.full(g.n, -1, dtype=np.int64)
    next_id = 0
    for seed in range(g.n):
        if not mask[seed] or comp[seed] >= 0:
            continue
        comp[seed] = next_id
        stack = [seed]
        while stack:
            v = stack.pop()
            for u in g.neighbors(v):
                u = int(u)
                if mask[u] and comp[u] < 0:
                    comp[u] = next_id
                    stack.append(u)
        next_id += 1
    return comp


def _random_graph(rng: np.random.Generator, n: int, extra: int) -> Graph:
    """Random tree (guaranteed connected) plus ``extra`` random edges."""
    parents = [int(rng.integers(0, i)) for i in range(1, n)]
    src = list(range(1, n)) + [int(x) for x in rng.integers(0, n, extra)]
    dst = parents + [int(x) for x in rng.integers(0, n, extra)]
    return Graph.from_edges(n, np.array(src), np.array(dst))


@st.composite
def connected_sbms(draw):
    """Small connected SBM-ish graphs: planted blocks plus a spanning tree."""
    n = draw(st.integers(min_value=12, max_value=80))
    blocks = draw(st.integers(min_value=2, max_value=5))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    block_of = rng.integers(0, blocks, n)
    # spanning tree for connectivity
    parents = [int(rng.integers(0, i)) for i in range(1, n)]
    src = list(range(1, n)); dst = parents
    # dense-ish intra-block edges, sparse inter-block
    for b in range(blocks):
        members = np.where(block_of == b)[0]
        if members.size >= 2:
            m_in = 3 * members.size
            src += [int(x) for x in members[rng.integers(0, members.size, m_in)]]
            dst += [int(x) for x in members[rng.integers(0, members.size, m_in)]]
    extra = draw(st.integers(min_value=0, max_value=n))
    src += [int(x) for x in rng.integers(0, n, extra)]
    dst += [int(x) for x in rng.integers(0, n, extra)]
    return Graph.from_edges(n, np.array(src), np.array(dst))


# ---------------------------------------------------------------------------
# quotient_edges — THE quotient/cut builder
# ---------------------------------------------------------------------------
def test_quotient_edges_matches_brute_force():
    g = karate_club()
    labels = leiden(g, seed=0)
    q = quotient_edges(g, labels)
    src, dst, w = g.arcs()
    ls, ld = labels[src], labels[dst]
    for a, b, qw in zip(q.src, q.dst, q.weight):
        assert a != b
        assert qw == pytest.approx(w[(ls == a) & (ld == b)].sum())
    # intra: per-community internal undirected weight
    for c in range(q.k):
        intra = labels[src] == labels[dst]
        expect = w[intra & (ls == c)].sum() / 2.0
        assert q.intra[c] == pytest.approx(expect)
    assert q.node_weight.sum() == pytest.approx(g.node_weight.sum())


def test_quotient_edges_symmetric_and_sorted():
    g = karate_club()
    labels = leiden(g, seed=0)
    q = quotient_edges(g, labels)
    # sorted lexicographically by (src, dst)
    key = q.src * q.k + q.dst
    assert (np.diff(key) > 0).all()
    # every arc has its reciprocal with equal weight
    fwd = {(int(a), int(b)): float(x)
           for a, b, x in zip(q.src, q.dst, q.weight)}
    for (a, b), x in fwd.items():
        assert fwd[(b, a)] == pytest.approx(x)


def test_community_cuts_is_a_quotient_view():
    g = karate_club()
    labels = leiden(g, seed=0)
    q = quotient_edges(g, labels)
    cuts = community_cuts(g, labels)
    assert sum(len(v) for v in cuts.values()) == q.src.size
    for a, b, w in zip(q.src, q.dst, q.weight):
        assert cuts[int(a)][int(b)] == pytest.approx(float(w))


def test_aggregate_routes_through_quotient():
    """Graph.aggregate is a thin view of quotient_edges: CSR == arc arrays."""
    g = karate_club()
    labels = leiden(g, seed=0)
    agg = g.aggregate(labels)
    q = quotient_edges(g, labels)
    np.testing.assert_array_equal(agg.indptr, q.indptr())
    np.testing.assert_array_equal(agg.indices, q.dst.astype(np.int32))
    np.testing.assert_allclose(agg.edge_weight, q.weight)
    np.testing.assert_allclose(agg.self_weight, q.intra)
    np.testing.assert_allclose(agg.node_weight, q.node_weight)
    assert agg.m == pytest.approx(g.m)


# ---------------------------------------------------------------------------
# connected_components — array union-find vs. the BFS reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_union_find_matches_bfs_numbering(seed):
    rng = np.random.default_rng(seed)
    n = 60
    # a deliberately fragmented graph: a few small trees
    src, dst = [], []
    for lo in range(0, n - 10, 15):
        hi = lo + int(rng.integers(5, 12))
        for v in range(lo + 1, min(hi, n)):
            src.append(v); dst.append(lo + int(rng.integers(0, v - lo)))
    g = Graph.from_edges(n, np.array(src), np.array(dst))
    np.testing.assert_array_equal(g.connected_components(),
                                  _bfs_components(g))
    mask = rng.random(n) < 0.7
    np.testing.assert_array_equal(g.connected_components(mask),
                                  _bfs_components(g, mask))


def test_union_find_isolated_nodes_and_empty_mask():
    g = Graph.from_edges(5, [0, 1], [1, 2], None)
    comp = connected_components(g.n, *g.arcs()[:2])
    assert comp.tolist() == [0, 0, 0, 1, 2]
    none = g.connected_components(np.zeros(5, dtype=bool))
    assert (none == -1).all()


def test_split_components_vectorized():
    g = Graph.from_edges(6, [0, 2, 4], [1, 3, 5], None)
    labels = np.array([0, 0, 0, 0, 1, 1])
    out = split_components(g, labels)
    assert len(np.unique(out)) == 3
    # compact ids, every community connected
    assert set(np.unique(out)) == {0, 1, 2}


# ---------------------------------------------------------------------------
# CommunityState — incrementally merged adjacency stays exact
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [3, 7])
def test_community_state_matches_fresh_quotient_after_merges(seed):
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, 50, 120)
    labels = leiden(g, seed=0)
    state = CommunityState(g, labels)
    num = state.num
    for _ in range(num - 2):
        alive = np.flatnonzero(state.alive)
        a, b = rng.choice(alive, size=2, replace=False)
        state.merge(int(b), into=int(a))
        # the state's view of a's neighborhood must equal a from-scratch
        # quotient of the merged labelling
        merged = state.compact_labels()
        q = quotient_edges(g, merged)
        root = state.roots()
        _, compact = np.unique(root, return_inverse=True)
        ca = compact[int(a)]
        nbrs, ws = state.neighbors(int(a))
        sel = q.src == ca
        np.testing.assert_array_equal(np.sort(compact[nbrs]), q.dst[sel])
        order = np.argsort(compact[nbrs])
        np.testing.assert_allclose(ws[order], q.weight[sel])
    # sizes survive arbitrary merge sequences
    merged = state.compact_labels()
    sizes = np.bincount(merged)
    live = np.flatnonzero(state.alive)
    root = state.roots()
    _, compact = np.unique(root, return_inverse=True)
    np.testing.assert_allclose(np.sort(state.size[live]),
                               np.sort(sizes.astype(float)))


# ---------------------------------------------------------------------------
# fuse — disconnected fallback pops the heap (satellite regression)
# ---------------------------------------------------------------------------
def test_fuse_disconnected_input_uses_heap_fallback():
    """A community with no neighbors (disconnected input) must merge with
    the smallest other live community and still reach exactly k."""
    # two disjoint paths + two isolated nodes
    g = Graph.from_edges(8, [0, 1, 3, 4], [1, 2, 4, 5], None)
    labels = np.arange(8, dtype=np.int64)          # singletons
    out = fuse(g, labels, 2, max_part_size=8.0)
    assert int(out.max()) + 1 == 2
    # deterministic across calls
    np.testing.assert_array_equal(out, fuse(g, labels, 2, max_part_size=8.0))


def test_fuse_no_inter_community_arcs():
    """Labelling with ZERO inter-community arcs (labels == components of a
    disconnected graph): every merge goes through the heap fallback and the
    empty-quotient bincount must not crash CommunityState."""
    g = Graph.from_edges(6, [0, 2, 4], [1, 3, 5], None)
    labels = np.array([0, 0, 1, 1, 2, 2], dtype=np.int64)
    out = fuse(g, labels, 2, max_part_size=10.0)
    assert int(out.max()) + 1 == 2


def test_quotient_edges_rejects_bad_self_weight():
    g = karate_club()
    labels = np.zeros(g.n, dtype=np.int64)
    with pytest.raises(ValueError):
        quotient_edges(g, labels, self_weight=np.zeros(3))


def test_fuse_disconnected_many_components_terminates_fast():
    """O(|C| log |C|) fallback: hundreds of isolated nodes fuse quickly and
    exactly (the old O(|C|^2) scan made this quadratic)."""
    n = 400
    # edges only among the first 100 nodes; 300 isolated nodes
    rng = np.random.default_rng(0)
    src = rng.integers(0, 100, 300)
    dst = rng.integers(0, 100, 300)
    keep = src != dst
    g = Graph.from_edges(n, src[keep], dst[keep], None)
    out = fuse(g, np.arange(n, dtype=np.int64), 4, max_part_size=n)
    assert int(out.max()) + 1 == 4
    assert out.shape == (n,)


# ---------------------------------------------------------------------------
# end-to-end invariants over random connected SBMs (hypothesis)
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(g=connected_sbms(), k=st.integers(min_value=2, max_value=4),
       seed=st.integers(min_value=0, max_value=3))
def test_property_engine_leiden_fusion_invariants(g, k, seed):
    """The satellite invariants: exactly k partitions, each one connected
    component, zero isolated nodes, sizes within the (n/k)(1+alpha) cap
    modulo the documented overflow case (no fitting neighbor -> Algorithm 2
    merges into the smallest neighbor anyway), and per-seed determinism."""
    alpha = 1.0
    labels = leiden_fusion(g, k, alpha=alpha, seed=seed)
    assert int(labels.max()) + 1 == k
    rep = evaluate_partition(g, labels)
    assert rep.components_per_part == [1] * k
    assert rep.total_isolated == 0
    cap = (g.n / k) * (1.0 + alpha)
    sizes = np.bincount(labels, minlength=k)
    overflow = sizes[sizes > cap]
    # documented overflow: at most one partition may exceed the cap, and
    # only because every fitting merge was exhausted
    assert overflow.size <= 1, (sizes, cap)
    # determinism: same seed, same labels, bit for bit
    np.testing.assert_array_equal(labels,
                                  leiden_fusion(g, k, alpha=alpha, seed=seed))


@settings(max_examples=15, deadline=None)
@given(g=connected_sbms())
def test_property_leiden_communities_connected(g):
    """The vectorized local move + refinement still guarantees connected
    communities (enforced by the engine's component split)."""
    labels = leiden(g, seed=0)
    for c in range(int(labels.max()) + 1):
        assert g.num_components(labels == c) == 1
