"""Tests for repro.obs — tracing + metrics (DESIGN.md §16).

Covers the contracts the rest of the stack leans on: span
nesting/exception-safety, trace JSON schema validity, byte-identical
pipeline results in no-op mode, deterministic counter snapshots across
processes, and the ``PipelineReport.timings``-is-a-view-over-spans pin.
"""
from __future__ import annotations

import json
import subprocess
import sys

import pytest

from repro import obs
from repro.obs.metrics import MetricsRegistry, pow2_bucket_index
from repro.obs.summarize import (format_summary, load_trace,
                                 summarize_trace, validate_trace)


@pytest.fixture(autouse=True)
def _obs_isolation():
    obs.reset()
    yield
    obs.reset()


# ---------------------------------------------------------------------------
# spans: no-op fast path, nesting, exception safety
# ---------------------------------------------------------------------------
def test_disabled_span_is_shared_noop_singleton():
    assert not obs.enabled()
    s1 = obs.span("a.b", x=1)
    s2 = obs.span("c.d")
    assert s1 is s2                      # one shared object, no allocation
    with s1 as sp:
        sp.set(anything=True)            # must be accepted and dropped
    assert sp.duration is None
    assert obs.tracer().event_count() == 0


def test_span_nesting_records_depth_and_containment():
    obs.enable()
    with obs.span("outer.stage") as outer:
        with obs.span("inner.step", i=0) as inner:
            pass
        with obs.span("inner.step", i=1):
            pass
    spans = obs.tracer().spans()
    assert [s.name for s in spans] == \
        ["inner.step", "inner.step", "outer.stage"]
    assert outer.depth == 0 and inner.depth == 1
    assert all(s.duration is not None and s.duration >= 0 for s in spans)
    # children close before the parent and fit inside it
    assert outer.duration >= inner.duration


def test_span_exception_safety_stamps_error_and_unwinds():
    obs.enable()
    with pytest.raises(ValueError):
        with obs.span("boom.outer"):
            with obs.span("boom.inner"):
                raise ValueError("expected")
    spans = {s.name: s for s in obs.tracer().spans()}
    assert set(spans) == {"boom.outer", "boom.inner"}
    assert spans["boom.inner"].attrs["error"] == "ValueError"
    assert spans["boom.outer"].attrs["error"] == "ValueError"
    assert all(s.duration is not None for s in spans.values())
    # the stack fully unwound: a fresh span is depth 0 again
    with obs.span("after.exc") as sp:
        pass
    assert sp.depth == 0


def test_generator_abandonment_closes_orphaned_spans():
    obs.enable()

    def gen():
        with obs.span("gen.chunk"):
            yield 1
            yield 2

    with obs.span("consumer.loop"):
        for _ in gen():
            break                        # abandon mid-span
    names = [s.name for s in obs.tracer().spans()]
    assert "gen.chunk" in names and "consumer.loop" in names
    assert all(s.duration is not None for s in obs.tracer().spans())


# ---------------------------------------------------------------------------
# trace document: schema validity, export round-trip, summarize
# ---------------------------------------------------------------------------
def test_trace_document_is_valid_chrome_trace(tmp_path):
    obs.enable()
    with obs.span("pipeline.total"):
        with obs.span("pipeline.dataset", n=34):
            pass
    obs.counter("graphstore.chunks").inc(3)
    path = obs.export_trace(str(tmp_path / "t.json"))
    doc = load_trace(path)
    assert validate_trace(doc) == []
    assert doc["schema"] == "repro-obs-trace"
    assert doc["version"] == obs.SCHEMA_VERSION
    assert doc["displayTimeUnit"] == "ms"
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert {e["name"] for e in xs} == {"pipeline.total", "pipeline.dataset"}
    for e in xs:
        assert e["dur"] >= 0 and "ts" in e and "pid" in e and "tid" in e
        assert e["cat"] == "pipeline"
        assert "depth" in e["args"]
    assert doc["metrics"]["graphstore.chunks"]["value"] == 3


def test_validate_trace_require_matching():
    obs.enable()
    with obs.span("pipeline.dataset"):
        pass
    doc = obs.trace_document()
    # exact, category, prefix, and suffix forms all match
    for req in ("pipeline.dataset", "pipeline", "dataset"):
        assert validate_trace(doc, require=[req]) == [], req
    assert validate_trace(doc, require=["train"]) != []


def test_validate_trace_flags_malformed_documents():
    assert validate_trace({}) != []
    assert validate_trace({"schema": "wrong", "version": 1,
                           "traceEvents": []}) != []
    bad_event = {"schema": "repro-obs-trace", "version": 1,
                 "traceEvents": [{"ph": "X", "name": "a", "ts": 0.0,
                                  "dur": -5.0, "pid": 1, "tid": 1}]}
    assert any("dur" in p for p in validate_trace(bad_event))


def test_summarize_aggregates_per_name(tmp_path):
    obs.enable()
    for i in range(3):
        with obs.span("engine.sweep", i=i):
            pass
    doc = obs.trace_document()
    rows = summarize_trace(doc)
    row = next(r for r in rows if r["name"] == "engine.sweep")
    assert row["count"] == 3
    text = format_summary(doc)
    assert "engine.sweep" in text


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_registry_counter_gauge_histogram_snapshot():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(4)
    reg.gauge("g").set(2.5)
    for v in (1, 2, 3, 900):
        reg.histogram("h").record(v)
    snap = reg.snapshot()
    assert snap["c"] == {"kind": "counter", "value": 5}
    assert snap["g"]["value"] == 2.5
    h = snap["h"]["value"]
    assert h["count"] == 4 and h["min"] == 1 and h["max"] == 900
    assert reg.total_ops() == 7
    with pytest.raises(TypeError):
        reg.gauge("c")                   # kind mismatch is a hard error


def test_pow2_bucket_index():
    assert pow2_bucket_index(0) == 0
    assert pow2_bucket_index(1) == 0
    assert pow2_bucket_index(2) == 1
    assert pow2_bucket_index(3) == 2
    assert pow2_bucket_index(1024) == 10
    assert pow2_bucket_index(1025) == 11


_SNAPSHOT_SCRIPT = """
import json
from repro.obs.metrics import MetricsRegistry
reg = MetricsRegistry()
for i in range(100):
    reg.counter("a.ops").inc()
    if i % 3 == 0:
        reg.counter("b.ops").inc(2)
reg.gauge("ignored.gauge").set(1.0)      # filtered out by kinds=
print(json.dumps(reg.snapshot(kinds=("counter",)), sort_keys=True))
"""


def test_counter_snapshot_deterministic_across_processes():
    """Two fresh interpreters doing the same work emit identical counter
    snapshots — the property that makes registry counters usable as
    primary storage for cross-process comparisons."""
    outs = [subprocess.run([sys.executable, "-c", _SNAPSHOT_SCRIPT],
                           capture_output=True, text=True, check=True,
                           env=_child_env()).stdout
            for _ in range(2)]
    assert outs[0] == outs[1]
    snap = json.loads(outs[0])
    assert snap == {"a.ops": {"kind": "counter", "value": 100},
                    "b.ops": {"kind": "counter", "value": 68}}


def _child_env():
    import os
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


# ---------------------------------------------------------------------------
# pipeline integration: no-op byte-identity + timings-as-span-view pin
# ---------------------------------------------------------------------------
def _tiny_report():
    from repro.pipeline import Pipeline, PipelineConfig
    cfg = PipelineConfig(dataset="karate", method="leiden_fusion", k=2,
                         mode="local", epochs=2, classifier_epochs=4,
                         collect_hlo=False, cache_dir=None)
    return Pipeline(cfg).run()


def test_noop_mode_byte_identical_and_timings_pin():
    # run 1: tracing disabled (the default production path)
    assert not obs.enabled()
    plain = _tiny_report().as_dict()

    # run 2: tracing enabled
    obs.reset()
    obs.enable()
    traced_report = _tiny_report()
    traced = traced_report.as_dict()

    # byte-identity: tracing must not perturb any pipeline output —
    # only the wall-clock timings may differ between the two runs
    plain.pop("timings")
    timings = traced.pop("timings")
    assert json.dumps(plain, sort_keys=True, default=str) == \
        json.dumps(traced, sort_keys=True, default=str)

    # timings pin: the report's timings dict is a view over the spans
    durations = {s.name: s.duration for s in obs.tracer().spans()}
    for key, span_name in [("total", "pipeline.total"),
                           ("dataset", "pipeline.dataset"),
                           ("partition_stage", "pipeline.partition"),
                           ("train", "pipeline.train"),
                           ("classifier", "pipeline.classifier")]:
        assert timings[key] == round(durations[span_name], 4), key

    # the acceptance span set is present in the trace document
    doc = obs.trace_document()
    assert validate_trace(doc, require=["dataset", "partition", "train",
                                        "classifier"]) == []
    names = {s.name for s in obs.tracer().spans()}
    assert "engine.sweep" in names          # engine frontier sweeps
    assert "graphstore.chunk" in names      # chunk I/O spans
    assert "train.epoch" in names           # per-epoch training spans
