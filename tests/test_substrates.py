"""Unit tests: optimizer, schedules, checkpointing, HLO analysis, sharding
rules, expert placement."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import (adamw_init, adamw_update, constant_schedule,
                         cosine_schedule, linear_warmup_cosine)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2)

    p = params
    for _ in range(300):
        g = jax.grad(loss_fn)(p)
        p, opt = adamw_update(g, opt, p, lr=0.1)
    assert float(loss_fn(p)) < 1e-3


def test_adamw_weight_decay_shrinks():
    p = {"w": jnp.ones((4,))}
    opt = adamw_init(p)
    g = {"w": jnp.zeros((4,))}
    p2, _ = adamw_update(g, opt, p, lr=0.1, weight_decay=0.5)
    assert float(p2["w"][0]) < 1.0


def test_grad_clipping_bounds_update():
    p = {"w": jnp.zeros((2,))}
    opt = adamw_init(p)
    g = {"w": jnp.asarray([1e9, 1e9])}
    p2, _ = adamw_update(g, opt, p, lr=0.1, clip_norm=1.0)
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_schedules_shapes():
    for sched, checks in [
        (constant_schedule(1e-3), [(0, 1e-3), (100, 1e-3)]),
        (cosine_schedule(1.0, 100), [(0, 1.0), (100, 0.1)]),
        (linear_warmup_cosine(1.0, 10, 100), [(0, 0.0), (10, 1.0)]),
    ]:
        for step, expect in checks:
            got = float(sched(jnp.asarray(step)))
            assert abs(got - expect) < 0.05, (step, got, expect)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": [jnp.ones(4), {"c": jnp.zeros(())}]}
    save_checkpoint(str(tmp_path), 3, tree)
    save_checkpoint(str(tmp_path), 7, jax.tree.map(lambda x: x + 1, tree))
    assert latest_step(str(tmp_path)) == 7
    out = restore_checkpoint(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.arange(6).reshape(2, 3) + 1)
    # wrong shape rejected
    bad = {"a": jnp.zeros((9, 9)), "b": tree["b"]}
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)


# ---------------------------------------------------------------------------
# HLO analysis
# ---------------------------------------------------------------------------
def test_collective_bytes_parses_ops():
    from repro.launch.hlo_analysis import collective_bytes
    hlo = """
  %ag = f32[16,128]{1,0} all-gather(f32[1,128]{1,0} %x), dimensions={0}
  %ar.1 = bf16[256]{0} all-reduce(bf16[256]{0} %y), to_apply=%add
  %cp = f32[8]{0} collective-permute(f32[8]{0} %z), source_target_pairs={{0,1}}
  %t = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(f32[4,4]{1,0} %a, f32[4,4]{1,0} %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 128 * 4
    assert out["all-reduce"] == 256 * 2
    assert out["collective-permute"] == 8 * 4
    assert out["all-to-all"] == 2 * 16 * 4
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "all-to-all",
                                "collective-permute", "reduce-scatter"))


def test_collective_bytes_ignores_done_halves():
    from repro.launch.hlo_analysis import collective_bytes
    hlo = """
  %ags = f32[64]{0} all-gather-start(f32[4]{0} %x)
  %agd = f32[64]{0} all-gather-done(f32[64]{0} %ags)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 64 * 4          # counted once


def test_roofline_terms_dominance():
    from repro.launch.hlo_analysis import roofline_terms
    t = roofline_terms(flops=197e12, hbm_bytes=0, coll_bytes=0, chips=1)
    assert t["dominant"] == "compute" and abs(t["compute_s"] - 1.0) < 1e-9
    t = roofline_terms(flops=0, hbm_bytes=819e9, coll_bytes=0, chips=1)
    assert t["dominant"] == "memory"
    t = roofline_terms(flops=0, hbm_bytes=0, coll_bytes=50e9, chips=1)
    assert t["dominant"] == "collective" and abs(t["collective_s"] - 1) < 1e-9


# ---------------------------------------------------------------------------
# sharding rules (structure only; multi-device behaviour is covered by the
# dry-run and tests/test_distributed_gnn.py)
# ---------------------------------------------------------------------------
def test_param_shardings_divisibility_guard():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import _guard
    mesh = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        shape = {"model": 16, "data": 4}
    fm = FakeMesh()
    assert _guard(fm, P("model"), (32,)) == P("model")
    assert _guard(fm, P("model"), (30,)) == P(None)
    assert _guard(fm, P(("data",)), (8,)) == P(("data",))


def test_param_shardings_rules_applied():
    from repro.configs import get_config
    from repro.launch.sharding import param_shardings
    from repro.launch.steps import params_spec
    cfg = get_config("qwen3_4b")
    # single-device mesh named like production axes
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sds = params_spec(cfg)
    sh = param_shardings(mesh, sds, "dp_tp")
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    by_name = {"/".join(str(getattr(p, 'key', getattr(p, 'idx', '?')))
                        for p in path): s.spec for path, s in flat}
    assert by_name["embed"][0] == "model"
    assert by_name["layers/attn/wq"][-1] == "model"
    assert by_name["layers/ffn/w_gate"][-1] == "model"
    assert by_name["layers/ffn/w_out"][-2] == "model"


def test_moe_expert_axis_sharded():
    from repro.configs import get_config
    from repro.launch.sharding import param_shardings
    from repro.launch.steps import params_spec
    cfg = get_config("qwen2_moe_a2p7b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sds = params_spec(cfg)
    sh = param_shardings(mesh, sds, "dp_tp")
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    for path, s in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", "?")))
                        for p in path)
        if name == "layers/ffn/w_gate":        # [L, E, d, f]
            assert s.spec[1] == "model", s.spec   # expert-parallel
            return
    raise AssertionError("moe stack not found")


# ---------------------------------------------------------------------------
# expert placement (beyond-paper)
# ---------------------------------------------------------------------------
def test_lf_expert_placement_balanced_and_better():
    from repro.core.expert_placement import (contiguous_placement,
                                             lf_expert_placement,
                                             placement_cost)
    rng = np.random.default_rng(0)
    num_experts, shards, k = 16, 4, 2
    # clustered router: tokens pick both experts from one random block of 4
    blocks = np.arange(num_experts).reshape(4, 4)
    # scatter blocks so contiguous placement is wrong
    rng.shuffle(blocks.reshape(-1))
    trace = np.zeros((4000, k), dtype=np.int64)
    for t in range(4000):
        b = blocks[rng.integers(4)]
        trace[t] = rng.choice(b, size=k, replace=False)
    lf = lf_expert_placement(trace, num_experts, shards)
    assert np.bincount(lf, minlength=shards).tolist() == [4, 4, 4, 4]
    naive = contiguous_placement(num_experts, shards)
    c_lf = placement_cost(trace, lf)["mean_shards_per_token"]
    c_naive = placement_cost(trace, naive)["mean_shards_per_token"]
    assert c_lf <= c_naive
    assert c_lf < 1.1       # LF should recover the planted blocks


def test_apply_placement_permutes_experts():
    from repro.core.expert_placement import apply_placement_to_params
    e, d, f = 6, 4, 8
    params = {"router": np.arange(d * e).reshape(d, e).astype(np.float32),
              "w_gate": np.arange(e * d * f).reshape(e, d, f).astype(
                  np.float32)}
    placement = np.array([1, 0, 1, 0, 1, 0])
    out, perm = apply_placement_to_params(params, placement)
    # experts of shard 0 come first
    assert (placement[perm] == np.array([0, 0, 0, 1, 1, 1])).all()
    np.testing.assert_array_equal(out["w_gate"][0], params["w_gate"][perm[0]])
    np.testing.assert_array_equal(out["router"][:, 0],
                                  params["router"][:, perm[0]])
