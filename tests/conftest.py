"""Test-session bootstrap.

1. Puts ``src/`` on ``sys.path`` so ``python -m pytest`` works without the
   ``PYTHONPATH=src`` prefix.
2. Installs a minimal ``hypothesis`` fallback when the real package is not
   available (it is an optional dev dependency; see requirements-dev.txt).
   The shim supports exactly the surface the test suite uses — ``given``
   (keyword strategies), ``settings(max_examples=, deadline=)``,
   ``strategies.integers`` and ``strategies.composite`` — running each
   property test over a deterministic sample of drawn inputs. With real
   hypothesis installed (as in CI) the shim is inert.
"""
from __future__ import annotations

import functools
import os
import sys
import types
import zlib

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def _install_hypothesis_shim() -> None:
    import numpy as np

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

    def integers(min_value=None, max_value=None):
        lo = 0 if min_value is None else int(min_value)
        hi = 2**31 - 1 if max_value is None else int(max_value)
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    def composite(fn):
        @functools.wraps(fn)
        def builder(*args, **kwargs):
            def draw_fn(rng):
                return fn(lambda s: s._draw(rng), *args, **kwargs)
            return _Strategy(draw_fn)
        return builder

    def settings(max_examples: int = 10, deadline=None, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strategy_kw):
        def deco(fn):
            # NOTE: deliberately not functools.wraps — pytest would follow
            # __wrapped__ to the original signature and demand fixtures for
            # the strategy-drawn parameters. The wrapper takes no arguments;
            # every parameter comes from a strategy (the suite's only usage).
            def wrapper():
                n = getattr(wrapper, "_shim_max_examples", 10)
                name_seed = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = np.random.default_rng([name_seed, i])
                    drawn = {k: s._draw(rng)
                             for k, s in strategy_kw.items()}
                    try:
                        fn(**drawn)
                    except _ShimAssumption:
                        continue        # failed assume(): skip this example
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    class _ShimAssumption(Exception):
        pass

    def assume(condition) -> bool:
        # The shim cannot resample; a failed assumption skips the current
        # example (caught in the given() wrapper).
        if not condition:
            raise _ShimAssumption()
        return True

    mod = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.composite = composite
    mod.strategies = st_mod
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.__version__ = "0.0-shim"
    mod.__is_shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()
