"""Tests for the paper's core: graph structure, Leiden, Fusion, baselines."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Graph, karate_club, leiden, leiden_fusion, fuse,
                        evaluate_partition, make_arxiv_like,
                        lpa_partition, metis_partition, random_partition,
                        with_fusion, split_into_components)
from repro.core.fusion import community_cuts


# ---------------------------------------------------------------------------
# Graph structure
# ---------------------------------------------------------------------------
def test_karate_shape():
    g = karate_club()
    assert g.n == 34
    assert g.m == 78.0
    assert g.num_components() == 1


def test_from_edges_symmetrizes_and_dedups():
    g = Graph.from_edges(4, [0, 1, 1, 3], [1, 0, 2, 3], [1.0, 2.0, 1.0, 9.0])
    # (0,1) deduped to weight 3, (1,2) weight 1, self-loop (3,3) dropped
    assert g.m == 4.0
    assert set(g.neighbors(1).tolist()) == {0, 2}


def test_aggregate_preserves_total_weight_and_degrees():
    g = karate_club()
    labels = leiden(g, seed=0)
    agg = g.aggregate(labels)
    assert agg.m == pytest.approx(g.m)           # self-loops keep the mass
    assert agg.degrees().sum() == pytest.approx(g.degrees().sum())
    assert agg.node_weight.sum() == pytest.approx(g.n)


def test_connected_components_masked():
    g = Graph.from_edges(5, [0, 1, 3], [1, 2, 4], None)
    assert g.num_components() == 2
    mask = np.array([True, False, True, True, True])
    comp = g.connected_components(mask)
    assert comp[1] == -1
    assert g.num_components(mask) == 3           # {0}, {2}, {3,4}


# ---------------------------------------------------------------------------
# Leiden
# ---------------------------------------------------------------------------
def test_leiden_karate_four_communities():
    """Paper Fig. 2: Leiden finds 4 communities on the karate club."""
    labels = leiden(karate_club(), seed=0)
    assert int(labels.max()) + 1 == 4


def test_leiden_communities_connected():
    g = karate_club()
    labels = leiden(g, seed=0)
    for c in range(int(labels.max()) + 1):
        assert g.num_components(labels == c) == 1


def test_leiden_size_cap_respected():
    g = karate_club()
    labels = leiden(g, max_community_size=10, seed=0)
    assert np.bincount(labels).max() <= 10


def test_leiden_improves_modularity_over_singletons():
    g = karate_club()
    labels = leiden(g, seed=0)
    two_m = 2 * g.m
    deg = g.degrees()
    k = int(labels.max()) + 1
    src, dst, w = g.arcs()
    e_c = np.zeros(k)
    intra = labels[src] == labels[dst]
    np.add.at(e_c, labels[src[intra]], w[intra] / 2.0)
    K_c = np.zeros(k)
    np.add.at(K_c, labels, deg)
    Q = float((e_c / g.m - (K_c / two_m) ** 2).sum())
    assert Q > 0.3   # known karate optimum ~0.41; greedy should get close


# ---------------------------------------------------------------------------
# Fusion (Algorithms 1-2)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k", [2, 4, 8])
def test_leiden_fusion_guarantees(k):
    """Paper's central claim: connected input => each partition is ONE
    connected component with ZERO isolated nodes, sizes within (1+alpha)."""
    g = karate_club()
    labels = leiden_fusion(g, k, alpha=0.5, seed=0)  # loose alpha for n=34
    assert int(labels.max()) + 1 == k
    rep = evaluate_partition(g, labels)
    assert rep.components_per_part == [1] * k
    assert rep.total_isolated == 0


def test_fuse_reaches_exact_k():
    g = karate_club()
    start = np.arange(g.n)   # singletons
    out = fuse(g, start, 5, max_part_size=12)
    assert int(out.max()) + 1 == 5


def test_fuse_respects_cap_when_feasible():
    g = karate_club()
    labels = leiden_fusion(g, 2, alpha=0.2, seed=0)
    sizes = np.bincount(labels)
    assert sizes.max() <= (g.n / 2) * 1.2 + 1


def test_community_cuts_symmetric():
    g = karate_club()
    labels = leiden(g, seed=0)
    cuts = community_cuts(g, labels)
    for a in cuts:
        for b, w in cuts[a].items():
            assert cuts[b][a] == pytest.approx(w)


# ---------------------------------------------------------------------------
# Baselines + "+F"
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fn", [lpa_partition, metis_partition,
                                random_partition])
def test_baselines_produce_k_partitions(fn):
    g = karate_club()
    labels = fn(g, 2, seed=0)
    assert set(np.unique(labels)) <= {0, 1}


def test_metis_balanced():
    g = make_arxiv_like(n=2000, seed=3).graph
    labels = metis_partition(g, 4, seed=0)
    rep = evaluate_partition(g, labels)
    assert rep.node_balance < 1.25


def test_fusion_fixes_components_of_any_base():
    """Paper §5.4: +F makes METIS/LPA partitions single-component."""
    g = make_arxiv_like(n=1500, seed=4).graph
    for base in (metis_partition, lpa_partition, random_partition):
        labels = with_fusion(base, g, 4, seed=0)
        rep = evaluate_partition(g, labels)
        assert rep.components_per_part == [1, 1, 1, 1], base.__name__
        assert rep.total_isolated == 0


def test_split_into_components():
    g = Graph.from_edges(6, [0, 2, 4], [1, 3, 5], None)
    labels = np.array([0, 0, 0, 0, 1, 1])
    out = split_into_components(g, labels)
    # partition 0 has two components -> becomes two communities
    assert len(np.unique(out)) == 3


# ---------------------------------------------------------------------------
# Property tests (hypothesis): invariants on random connected graphs
# ---------------------------------------------------------------------------
@st.composite
def connected_graphs(draw):
    n = draw(st.integers(min_value=8, max_value=60))
    # random tree guarantees connectivity, plus extra random edges
    rng_seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(rng_seed)
    parents = [int(rng.integers(0, i)) for i in range(1, n)]
    src = list(range(1, n)); dst = parents
    extra = draw(st.integers(min_value=0, max_value=3 * n))
    src += [int(x) for x in rng.integers(0, n, extra)]
    dst += [int(x) for x in rng.integers(0, n, extra)]
    return Graph.from_edges(n, np.array(src), np.array(dst))


@settings(max_examples=25, deadline=None)
@given(g=connected_graphs(), k=st.integers(min_value=2, max_value=4))
def test_property_lf_partitions_connected_no_isolated(g, k):
    """THE paper guarantee, property-tested: for any connected graph, every
    LF partition is a single connected component with no isolated nodes."""
    labels = leiden_fusion(g, k, alpha=1.0, seed=0)
    assert int(labels.max()) + 1 == k
    rep = evaluate_partition(g, labels)
    assert rep.max_components == 1
    assert rep.total_isolated == 0


@settings(max_examples=20, deadline=None)
@given(g=connected_graphs())
def test_property_leiden_covers_all_nodes(g):
    labels = leiden(g, seed=1)
    assert labels.shape == (g.n,)
    assert (labels >= 0).all()
    # labels are compact
    assert set(np.unique(labels)) == set(range(int(labels.max()) + 1))


@settings(max_examples=20, deadline=None)
@given(g=connected_graphs(), k=st.integers(min_value=2, max_value=4))
def test_property_fuse_monotone_partition_count(g, k):
    """fuse() only merges: partition count decreases monotonically to k and
    every output community is a union of input communities."""
    start = leiden(g, seed=0)
    out = fuse(g, start, k, max_part_size=g.n)
    assert int(out.max()) + 1 == min(k, int(start.max()) + 1)
    # union property: each input community maps to exactly one output label
    for c in range(int(start.max()) + 1):
        assert len(np.unique(out[start == c])) == 1
