"""Pipeline subsystem tests: artifact store round-trip, load-or-compute
cache semantics, Pipeline orchestration, and a CLI smoke test on the karate
club graph."""
import json
import os
import re
import subprocess
import sys

import numpy as np
import pytest

from repro.core import PartitionerSpec, build_partition_batch, \
    build_halo_exchange, leiden_fusion
from repro.pipeline import (ARTIFACT_VERSION, Pipeline, PipelineConfig,
                            PartitionArtifactStore, get_dataset,
                            graph_fingerprint, make_karate_dataset)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture()
def karate():
    return make_karate_dataset()


@pytest.fixture()
def store(tmp_path):
    return PartitionArtifactStore(str(tmp_path / "cache"))


# ---------------------------------------------------------------------------
# datasets
# ---------------------------------------------------------------------------
def test_dataset_registry_normalizes_names():
    ds = get_dataset("arxiv-like", n=200, feature_dim=8, num_classes=4)
    assert ds.name == "arxiv_like" and ds.graph.n == 200
    with pytest.raises(KeyError, match="unknown dataset"):
        get_dataset("nope")


def test_karate_dataset_shapes(karate):
    assert karate.graph.n == 34
    assert karate.num_classes == 2
    assert karate.features.shape == (34, 34)
    assert set(np.unique(karate.labels)) == {0, 1}
    # masks partition the node set
    total = (karate.train_mask.astype(int) + karate.val_mask.astype(int)
             + karate.test_mask.astype(int))
    assert (total == 1).all()


def test_graph_fingerprint_is_content_addressed(karate):
    h1 = graph_fingerprint(karate.graph)
    h2 = graph_fingerprint(make_karate_dataset(seed=7).graph)
    assert h1 == h2          # same topology, different masks -> same hash
    other = get_dataset("arxiv-like", n=100, feature_dim=4, num_classes=2)
    assert graph_fingerprint(other.graph) != h1


# ---------------------------------------------------------------------------
# artifact store
# ---------------------------------------------------------------------------
def _assert_batches_equal(a, b):
    assert a.n_pad == b.n_pad and a.e_pad == b.e_pad and a.k == b.k
    for f in ("node_ids", "node_mask", "owned_mask", "edge_src", "edge_dst",
              "edge_weight", "in_degree"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))


def test_roundtrip_partition_save_load(karate, store):
    """partition -> save -> load gives back an identical PartitionBatch."""
    g = karate.graph
    first = store.load_or_compute(g, "leiden_fusion", 4, 0, "repli",
                                  with_halo=True)
    assert not first.labels_hit and not first.batch_hit
    assert os.path.exists(first.labels_path)
    assert os.path.exists(first.batch_path)

    second = store.load_or_compute(g, "leiden_fusion", 4, 0, "repli",
                                   with_halo=True)
    assert second.labels_hit and second.batch_hit
    np.testing.assert_array_equal(first.labels, second.labels)
    _assert_batches_equal(first.batch, second.batch)
    np.testing.assert_array_equal(first.halo.send_rows,
                                  second.halo.send_rows)
    np.testing.assert_array_equal(first.halo.recv_rows,
                                  second.halo.recv_rows)
    assert first.halo.h_pad == second.halo.h_pad

    # loaded bundle matches a from-scratch rebuild exactly
    fresh_labels = leiden_fusion(g, 4, seed=0)
    np.testing.assert_array_equal(second.labels, fresh_labels)
    fresh = build_partition_batch(g, fresh_labels, scheme="repli")
    _assert_batches_equal(second.batch, fresh)
    fresh_halo = build_halo_exchange(g, fresh_labels, fresh)
    np.testing.assert_array_equal(second.halo.send_rows,
                                  fresh_halo.send_rows)


def test_cache_hit_skips_repartitioning(karate, store, monkeypatch):
    """Second load must NOT invoke the partitioner again."""
    g = karate.graph
    store.load_or_compute(g, "leiden_fusion", 2, 0, "inner")

    def boom(*a, **k):
        raise AssertionError("partitioner re-invoked despite cache hit")
    import repro.pipeline.artifacts as artifacts_mod
    monkeypatch.setattr(artifacts_mod, "partition_from_spec", boom)
    bundle = store.load_or_compute(g, "leiden_fusion", 2, 0, "inner")
    assert bundle.labels_hit and bundle.batch_hit


def test_labels_shared_across_schemes(karate, store):
    """inner and repli runs share ONE labels artifact (partition once)."""
    g = karate.graph
    a = store.load_or_compute(g, "metis", 2, 0, "inner")
    b = store.load_or_compute(g, "metis", 2, 0, "repli")
    assert not a.labels_hit
    assert b.labels_hit                   # second scheme reuses the labels
    assert not b.batch_hit                # but assembles its own batch
    assert a.labels_path == b.labels_path
    assert a.batch_path != b.batch_path


def test_key_separates_method_k_seed(karate, store):
    g = karate.graph
    base = store.load_or_compute(g, "random", 2, 0, "inner")
    for method, k, seed in (("lpa", 2, 0), ("random", 4, 0),
                            ("random", 2, 1)):
        other = store.load_or_compute(g, method, k, seed, "inner")
        assert not other.labels_hit
        assert other.labels_path != base.labels_path


def test_halo_augments_cached_batch(karate, store):
    """A batch cached without halo gets upgraded in place when halo is
    requested — the batch itself is still a hit."""
    g = karate.graph
    a = store.load_or_compute(g, "leiden_fusion", 2, 0, "repli",
                              with_halo=False)
    assert a.halo is None
    b = store.load_or_compute(g, "leiden_fusion", 2, 0, "repli",
                              with_halo=True)
    assert b.batch_hit and b.halo is not None
    c = store.load_or_compute(g, "leiden_fusion", 2, 0, "repli",
                              with_halo=True)
    assert c.batch_hit and c.halo is not None
    np.testing.assert_array_equal(b.halo.send_rows, c.halo.send_rows)


def test_artifact_version_is_5():
    """v5 turns monolithic compressed npz bundles into directory bundles
    whose batch tensors memory-map per-partition shards (DESIGN.md §15);
    pre-v5 bundles must degrade to misses."""
    assert ARTIFACT_VERSION == 5


def test_v2_bundles_degrade_to_misses(karate, store):
    """A bundle written under the v2 key must be a MISS today (recompute),
    never a wrong hit — even when graph/spec/k/seed all match."""
    g = karate.graph
    spec = PartitionerSpec.parse("leiden_fusion")
    ghash = graph_fingerprint(g)
    # forge the exact bundle a v2 store would have written (npz file keyed
    # by a version=2 meta)
    v2_meta = store._labels_meta(ghash, spec, 2, 0)
    v2_meta["version"] = 2
    v2_path = store._path(v2_meta, spec) + ".npz"
    bogus = np.zeros(g.n, dtype=np.int64)       # stale labels, must not leak
    store._atomic_savez(v2_path, labels=bogus,
                        meta_json=np.asarray(json.dumps(v2_meta)))
    labels, hit, path, _ = store.load_or_partition(g, spec, 2, 0)
    assert not hit                              # degraded to a miss
    assert path != v2_path                      # current keys land elsewhere
    assert os.path.exists(v2_path)              # v2 bundle left untouched
    assert int(labels.max()) + 1 == 2           # freshly recomputed


def test_v4_bundles_degrade_to_misses(karate, store):
    """The v4->v5 format skew: a monolithic npz bundle keyed version=4 must
    be a clean MISS under the v5 store — the on-disk format changed (npz ->
    mmap directory bundle), so old bundles can never be half-read as new
    ones. Mirrors the v2->v3 engine-skew guarantee one format later."""
    g = karate.graph
    spec = PartitionerSpec.parse("leiden_fusion")
    ghash = graph_fingerprint(g)
    v4_meta = store._labels_meta(ghash, spec, 2, 0)
    v4_meta["version"] = 4
    v4_path = store._path(v4_meta, spec) + ".npz"
    bogus = np.full(g.n, 1, dtype=np.int64)     # stale labels, must not leak
    store._atomic_savez(v4_path, labels=bogus,
                        meta_json=np.asarray(json.dumps(v4_meta)))
    labels, hit, path, _ = store.load_or_partition(g, spec, 2, 0)
    assert not hit                              # degraded to a miss
    assert path != v4_path                      # v5 keys land elsewhere
    assert os.path.isdir(path)                  # v5 wrote a directory bundle
    assert os.path.exists(v4_path)              # v4 bundle left untouched
    assert not np.array_equal(labels, bogus)    # stale labels did not leak
    # the legacy npz still shows up in maintenance listings beside the v5
    # bundle directories, and clear() removes both kinds
    names = [name for name, _ in store.entries()]
    assert os.path.basename(v4_path) in names
    assert os.path.basename(path) in names
    assert store.clear() == len(names)
    assert store.entries() == []


def test_key_separates_partitioner_config(karate, store):
    """Regression for the v1 collision: same method, different
    hyperparameters must land in distinct cache entries."""
    g = karate.graph
    a = store.load_or_compute(g, "lpa(balance_cap=1.1)", 2, 0, "inner")
    b = store.load_or_compute(g, "lpa(balance_cap=2.0)", 2, 0, "inner")
    assert not a.labels_hit and not b.labels_hit     # no false sharing
    assert a.labels_path != b.labels_path
    assert a.batch_path != b.batch_path
    assert a.fingerprint != b.fingerprint
    # same spec -> hit on its own entry
    again = store.load_or_compute(g, "lpa(balance_cap=2.0)", 2, 0, "inner")
    assert again.labels_hit and again.labels_path == b.labels_path
    # equivalent spellings of one config share one entry
    spaced = store.load_or_compute(g, "lpa ( balance_cap = 2.0 )", 2, 0,
                                   "inner")
    assert spaced.labels_hit and spaced.labels_path == b.labels_path


def test_store_accepts_parsed_specs(karate, store):
    g = karate.graph
    spec = PartitionerSpec.parse("metis+f(alpha=0.2)")
    a = store.load_or_compute(g, spec, 2, 0, "inner")
    b = store.load_or_compute(g, "metis+f(alpha=0.2)", 2, 0, "inner")
    assert b.labels_hit and a.labels_path == b.labels_path
    assert a.spec == b.spec == "metis+f(alpha=0.2)"
    assert a.fingerprint == spec.fingerprint()


def test_corrupt_artifact_is_a_miss(karate, store):
    g = karate.graph
    a = store.load_or_compute(g, "random", 2, 0, "inner")
    with open(os.path.join(a.labels_path, "meta.json"), "w") as f:
        f.write("not json {")
    b = store.load_or_compute(g, "random", 2, 0, "inner")
    assert not b.labels_hit               # recomputed, not crashed
    np.testing.assert_array_equal(a.labels, b.labels)


# ---------------------------------------------------------------------------
# orchestrator
# ---------------------------------------------------------------------------
def test_pipeline_end_to_end_with_cache(tmp_path, karate):
    cfg = PipelineConfig(dataset="karate", method="leiden_fusion", k=4,
                         mode="local", epochs=3, classifier_epochs=10,
                         hidden_dim=16, embed_dim=16, num_layers=2,
                         dropout=0.0, cache_dir=str(tmp_path / "c"),
                         collect_hlo=True)
    rep1 = Pipeline(cfg).run(karate)
    assert not rep1.partition_cache_hit
    assert set(rep1.accuracy) == {"train", "val", "test"}
    assert rep1.partition["total_isolated"] == 0
    assert rep1.collectives["total"] == 0      # the paper's claim
    assert rep1.shapes["k"] == 4
    assert rep1.timings["total"] > 0

    rep2 = Pipeline(cfg).run(karate)
    assert rep2.partition_cache_hit and rep2.batch_cache_hit
    # deterministic end-to-end given identical config + cached partition
    assert rep1.accuracy == rep2.accuracy
    # report serializes
    json.dumps(rep2.as_dict())
    assert "cache HIT" in rep2.summary()


def test_pipeline_use_kernel_trains_and_matches_jnp_path(tmp_path, karate):
    """`--use-kernel` is a real training path: the run completes (it used
    to crash forward-only in jax.grad), records the flag, keeps the
    zero-collectives claim, and with dropout=0 lands within noise of the
    jnp path's accuracy."""
    def cfg(use_kernel):
        return PipelineConfig(dataset="karate", method="leiden_fusion", k=4,
                              mode="local", epochs=5, classifier_epochs=15,
                              hidden_dim=16, embed_dim=16, num_layers=2,
                              dropout=0.0, use_kernel=use_kernel,
                              cache_dir=str(tmp_path / "c"),
                              collect_hlo=use_kernel)
    rep_k = Pipeline(cfg(True)).run(karate)
    rep_j = Pipeline(cfg(False)).run(karate)
    assert rep_k.config["use_kernel"] is True
    # the summary names the resolved per-width strategies (DESIGN.md §14)
    assert "aggregation=kernel[" in rep_k.summary()
    assert rep_k.kernel, "resolved KernelConfigs must land in the report"
    for entry in rep_k.kernel.values():
        assert entry["strategy"] in ("pallas_fused", "pallas", "xla")
    assert "aggregation=jnp" in rep_j.summary()
    assert rep_j.kernel is None
    assert rep_k.collectives["total"] == 0    # kernel path stays local-only
    assert abs(rep_k.accuracy["test"] - rep_j.accuracy["test"]) <= 0.35
    for split in ("train", "val", "test"):
        assert 0.0 <= rep_k.accuracy[split] <= 1.0


def test_pipeline_centralized_reference(tmp_path, karate):
    cfg = PipelineConfig(dataset="karate", method="single", k=1,
                         scheme="inner", epochs=2, classifier_epochs=5,
                         hidden_dim=8, embed_dim=8, num_layers=2,
                         dropout=0.0, cache_dir=None, collect_hlo=False)
    rep = Pipeline(cfg).run(karate)
    assert rep.shapes["k"] == 1
    assert rep.collectives == {}


def test_pipeline_rejects_bad_mode(karate):
    cfg = PipelineConfig(dataset="karate", mode="nope")
    with pytest.raises(ValueError, match="mode"):
        Pipeline(cfg).run(karate)


def test_pipeline_rejects_bad_spec(karate):
    with pytest.raises(ValueError, match="unknown partitioner"):
        Pipeline(PipelineConfig(dataset="karate",
                                method="wat")).run(karate)
    with pytest.raises(ValueError, match="unknown field"):
        Pipeline(PipelineConfig(dataset="karate",
                                method="lpa(gamma=1)")).run(karate)


def test_pipeline_spec_string_end_to_end(tmp_path, karate):
    """The acceptance path: a configured +f spec runs end-to-end, the
    report records the canonical spec + fingerprint, re-running the same
    spec is a cache hit, and a different alpha is a miss."""
    def cfg(method):
        return PipelineConfig(dataset="karate", method=method, k=4,
                              mode="local", epochs=2, classifier_epochs=5,
                              hidden_dim=8, embed_dim=8, num_layers=2,
                              dropout=0.0, cache_dir=str(tmp_path / "c"),
                              collect_hlo=False)

    rep1 = Pipeline(cfg("lpa +f( alpha = 0.1 )")).run(karate)
    assert not rep1.partition_cache_hit
    assert rep1.config["method"] == "lpa+f(alpha=0.1)"   # canonical
    assert rep1.partition_fingerprint == \
        PartitionerSpec.parse("lpa+f(alpha=0.1)").fingerprint()
    assert rep1.partition["total_isolated"] == 0          # +f guarantee

    rep2 = Pipeline(cfg("lpa+f(alpha=0.1)")).run(karate)
    assert rep2.partition_cache_hit and rep2.batch_cache_hit

    rep3 = Pipeline(cfg("lpa+f(alpha=0.4)")).run(karate)
    assert not rep3.partition_cache_hit                   # config matters
    assert rep3.partition_fingerprint != rep1.partition_fingerprint
    assert "fp=" in rep3.summary()


# ---------------------------------------------------------------------------
# CLI smoke test (subprocess, as users invoke it)
# ---------------------------------------------------------------------------
def _run_cli(args, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.pipeline"] + args,
        capture_output=True, text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout + out.stderr


def test_cli_sync_mode_reports_collectives(tmp_path):
    """Sync mode (one partition per fake device) must report nonzero
    collective bytes — the traffic LF eliminates."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run(
        [sys.executable, "-m", "repro.pipeline", "run", "--dataset",
         "karate", "--method", "leiden_fusion", "--k", "4", "--mode",
         "sync", "--epochs", "3", "--classifier-epochs", "5",
         "--hidden-dim", "8", "--embed-dim", "8",
         "--cache-dir", str(tmp_path / "cache")],
        capture_output=True, text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-4000:]
    text = out.stdout + out.stderr
    m = re.search(r"collectives\s+(\d+) bytes/step", text)
    assert m, text
    assert int(m.group(1)) > 0


def test_cli_smoke_karate(tmp_path):
    args = ["run", "--dataset", "karate", "--method", "leiden_fusion",
            "--k", "4", "--mode", "local", "--epochs", "3",
            "--classifier-epochs", "10", "--hidden-dim", "16",
            "--embed-dim", "16", "--no-hlo",
            "--cache-dir", str(tmp_path / "cache")]
    out1 = _run_cli(args, tmp_path)
    assert "PipelineReport" in out1
    assert "accuracy" in out1
    assert "cache MISS" in out1
    out2 = _run_cli(args, tmp_path)
    assert "partition cache HIT" in out2
    assert "skipping re-partition" in out2

    listing = _run_cli(["cache", "--cache-dir", str(tmp_path / "cache")],
                       tmp_path)
    assert "labels-leiden_fusion-k4" in listing


def test_cli_accepts_spec_strings(tmp_path):
    """`run --method "lpa+f(alpha=0.1)"` works from the real CLI and caches
    under the spec fingerprint."""
    args = ["run", "--dataset", "karate", "--method", "lpa+f(alpha=0.1)",
            "--k", "4", "--mode", "local", "--epochs", "2",
            "--classifier-epochs", "5", "--hidden-dim", "8",
            "--embed-dim", "8", "--no-hlo",
            "--cache-dir", str(tmp_path / "cache")]
    out1 = _run_cli(args, tmp_path)
    assert "lpa+f(alpha=0.1)" in out1 and "cache MISS" in out1
    out2 = _run_cli(args, tmp_path)
    assert "partition cache HIT" in out2
    listing = _run_cli(["cache", "--cache-dir", str(tmp_path / "cache")],
                       tmp_path)
    assert "labels-lpa+f_alpha=0.1-k4" in listing


def test_cli_partitioners_lists_registry(tmp_path):
    out = _run_cli(["partitioners"], tmp_path)
    for name in ("leiden_fusion", "lpa", "metis", "random", "single"):
        assert name in out
    assert "connectivity|balanced" in out       # capability flags
    assert "resolution: float = 1.0" in out     # config schema + defaults
    assert "+f" in out and "spec grammar" in out

    js = _run_cli(["partitioners", "--json"], tmp_path)
    schema = json.loads(js[js.index("{"):])
    assert schema["lpa"]["fields"]["balance_cap"]["default"] == 1.1
    assert schema["leiden_fusion"]["capabilities"]["connectivity_guaranteed"]
    assert schema["+f"]["fields"]["alpha"]["default"] == 0.05
