"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the kernel bodies on CPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import (csr_aggregate, csr_aggregate_ref, flash_decode,
                           flash_decode_ref)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# csr_aggregate
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,f,e", [
    (8, 16, 32),          # tiny
    (100, 50, 700),       # unaligned everything
    (256, 128, 1024),     # exactly aligned
    (513, 130, 1500),     # off-by-one over tiles
    (64, 384, 256),       # multiple feature tiles
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_csr_aggregate_sweep(n, f, e, dtype):
    rng = np.random.default_rng(n * 7 + f)
    h = jnp.asarray(rng.normal(size=(n, f)), dtype)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(np.sort(rng.integers(0, n, e)), jnp.int32)
    w = jnp.asarray(rng.random(e), jnp.float32)
    out = csr_aggregate(h, src, dst, w, num_nodes=n)
    ref = csr_aggregate_ref(h, src, dst, w, num_nodes=n)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_csr_aggregate_zero_weight_edges_are_noops():
    h = jnp.ones((16, 8))
    src = jnp.zeros((10,), jnp.int32)
    dst = jnp.arange(10, dtype=jnp.int32)
    w = jnp.zeros((10,))
    out = csr_aggregate(h, src, dst, w, num_nodes=16)
    assert float(jnp.abs(out).max()) == 0.0


def test_csr_aggregate_duplicate_destinations_accumulate():
    h = jnp.eye(4, 8)
    src = jnp.asarray([0, 1, 2, 3], jnp.int32)
    dst = jnp.zeros((4,), jnp.int32)     # everything lands on row 0
    w = jnp.ones((4,))
    out = csr_aggregate(h, src, dst, w, num_nodes=4)
    np.testing.assert_allclose(np.asarray(out[0, :4]), np.ones(4), rtol=1e-6)
    assert float(jnp.abs(out[1:]).max()) == 0.0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.integers(4, 80), f=st.integers(1, 70), e=st.integers(1, 300))
def test_csr_aggregate_property(seed, n, f, e):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)  # unsorted is fine
    w = jnp.asarray(rng.random(e), jnp.float32)
    out = csr_aggregate(h, src, dst, w, num_nodes=n)
    ref = csr_aggregate_ref(h, src, dst, w, num_nodes=n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def _random_csr(seed, n, f, e, sorted_dst=False, dst_range=None):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    d = rng.integers(0, dst_range or n, e)
    dst = jnp.asarray(np.sort(d) if sorted_dst else d, jnp.int32)
    w = jnp.asarray(rng.random(e), jnp.float32)
    return h, src, dst, w


# ---------------------------------------------------------------------------
# csr_aggregate: custom VJP (the kernel is a real training path now)
# ---------------------------------------------------------------------------
def _grad_pair(h, src, dst, w, n):
    """(d/dh, d/dw) of a non-trivial scalar loss, kernel vs segment-sum."""
    def loss(agg_fn, h, w):
        out = agg_fn(h, src, dst, w, num_nodes=n)
        return (out * jnp.cos(h)).sum() + (out ** 2).sum()
    gk = jax.grad(lambda h, w: loss(csr_aggregate, h, w), (0, 1))(h, w)
    gr = jax.grad(lambda h, w: loss(csr_aggregate_ref, h, w), (0, 1))(h, w)
    return gk, gr


@pytest.mark.parametrize("n,f,e,sorted_dst", [
    (8, 16, 32, True),        # tiny
    (100, 50, 700, False),    # unaligned everything, unsorted dst
    (256, 128, 1024, True),   # exactly aligned
    (600, 30, 1500, False),   # node-tiled (> NODE_TILE after padding)
])
def test_csr_aggregate_grads_match_segment_sum(n, f, e, sorted_dst):
    h, src, dst, w = _random_csr(n * 3 + f, n, f, e, sorted_dst)
    (dh_k, dw_k), (dh_r, dw_r) = _grad_pair(h, src, dst, w, n)
    np.testing.assert_allclose(np.asarray(dh_k), np.asarray(dh_r),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(dw_k), np.asarray(dw_r),
                               rtol=3e-4, atol=3e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.integers(4, 90), f=st.integers(1, 80), e=st.integers(1, 400))
def test_csr_aggregate_grad_property(seed, n, f, e):
    """Hypothesis sweep for the custom VJP: arbitrary shapes (incl.
    non-multiples of every tile size), duplicate destinations, zero-degree
    nodes (dst restricted to the first half guarantees in-degree-0 nodes),
    unsorted dst — grads must match the segment-sum path."""
    h, src, dst, w = _random_csr(seed, n, f, e,
                                 dst_range=max(1, n // 2))
    (dh_k, dw_k), (dh_r, dw_r) = _grad_pair(h, src, dst, w, n)
    np.testing.assert_allclose(np.asarray(dh_k), np.asarray(dh_r),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(dw_k), np.asarray(dw_r),
                               rtol=5e-4, atol=5e-4)


def test_node_tiled_kernel_beyond_vmem_cap():
    """A partition with > 8192 nodes (the old whole-node-dimension VMEM cap)
    must aggregate correctly through the node-tiled grid, forward and
    backward."""
    n, f, e = 8700, 8, 4096
    h, src, dst, w = _random_csr(11, n, f, e, sorted_dst=True)
    out = csr_aggregate(h, src, dst, w, num_nodes=n)
    ref = csr_aggregate_ref(h, src, dst, w, num_nodes=n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
    dh_k = jax.grad(lambda h: csr_aggregate(
        h, src, dst, w, num_nodes=n).sum())(h)
    dh_r = jax.grad(lambda h: csr_aggregate_ref(
        h, src, dst, w, num_nodes=n).sum())(h)
    np.testing.assert_allclose(np.asarray(dh_k), np.asarray(dh_r),
                               rtol=3e-5, atol=3e-5)


def test_padding_contract_zero_weight_arcs_noop_on_both_paths():
    """THE padding contract (repro.kernels.ops): arcs with weight 0 are
    no-ops wherever they point — row 0 (the kernel wrapper's alignment
    padding), row N-1 (assemble's parked arcs), or anywhere else — on both
    the jnp and kernel paths, with unsorted dst, in value AND gradient."""
    from repro.gnn.layers import aggregate_mean
    rng = np.random.default_rng(5)
    n, f, e = 33, 7, 90
    h = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)     # unsorted
    w = jnp.asarray(rng.random(e), jnp.float32)
    deg = jnp.asarray(np.bincount(np.asarray(dst), weights=np.asarray(w) > 0,
                                  minlength=n), jnp.float32)
    # junk arcs: parked at row 0, at row N-1, and scattered — all weight 0
    junk_dst = np.concatenate([np.zeros(4), np.full(4, n - 1),
                               rng.integers(0, n, 4)]).astype(np.int32)
    junk_src = rng.integers(0, n, junk_dst.size).astype(np.int32)
    src2 = jnp.concatenate([src, jnp.asarray(junk_src)])
    dst2 = jnp.concatenate([dst, jnp.asarray(junk_dst)])
    w2 = jnp.concatenate([w, jnp.zeros(junk_dst.size, jnp.float32)])
    for use_kernel in (False, True):
        base = aggregate_mean(h, src, dst, w, deg, use_kernel)
        padded = aggregate_mean(h, src2, dst2, w2, deg, use_kernel)
        np.testing.assert_allclose(np.asarray(base), np.asarray(padded),
                                   rtol=1e-5, atol=1e-5)
        g_base = jax.grad(lambda h: aggregate_mean(
            h, src, dst, w, deg, use_kernel).var())(h)
        g_padded = jax.grad(lambda h: aggregate_mean(
            h, src2, dst2, w2, deg, use_kernel).var())(h)
        np.testing.assert_allclose(np.asarray(g_base), np.asarray(g_padded),
                                   rtol=1e-5, atol=1e-5)


def test_aggregate_mean_kernel_path_is_one_fused_call():
    """Degree normalization is fused into the kernel epilogue: the kernel
    path's jaxpr contains exactly one pallas_call (pallas strategy forced —
    on interpret-mode backends the autotuner resolves to "xla")."""
    from repro.gnn.layers import aggregate_mean
    from repro.kernels.autotune import KernelConfig, override
    h, src, dst, w = _random_csr(0, 16, 8, 24)
    deg = jnp.ones((16,))
    with override(KernelConfig(strategy="pallas")):
        jaxpr = str(jax.make_jaxpr(
            lambda h: aggregate_mean(h, src, dst, w, deg,
                                     use_kernel=True))(h))
    assert jaxpr.count("pallas_call") == 1


def test_aggregate_mean_kernel_path_xla_strategy_has_no_pallas_call():
    """On backends where the autotuner resolves to the "xla" strategy the
    kernel path must lower with NO interpret-mode pallas_call — same math,
    no emulator (DESIGN.md §14)."""
    from repro.gnn.layers import aggregate_mean
    from repro.kernels.autotune import KernelConfig, override
    h, src, dst, w = _random_csr(0, 16, 8, 24)
    deg = jnp.ones((16,))
    with override(KernelConfig(strategy="xla")):
        jaxpr = str(jax.make_jaxpr(
            lambda h: aggregate_mean(h, src, dst, w, deg,
                                     use_kernel=True))(h))
        out = aggregate_mean(h, src, dst, w, deg, use_kernel=True)
    assert jaxpr.count("pallas_call") == 0
    ref = aggregate_mean(h, src, dst, w, deg, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("hq,hkv,d,s,length", [
    (8, 8, 64, 600, 600),      # MHA, full cache
    (8, 2, 64, 1000, 777),     # GQA 4:1, partial
    (16, 1, 128, 2048, 1),     # MQA, single valid token
    (4, 4, 128, 512, 512),     # aligned block boundary
    (32, 8, 128, 1537, 1111),  # odd cache length
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(hq, hkv, d, s, length, dtype):
    rng = np.random.default_rng(hq * 131 + s)
    q = jnp.asarray(rng.normal(size=(hq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(s, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(s, hkv, d)), dtype)
    out = flash_decode(q, k, v, jnp.asarray(length))
    ref = flash_decode_ref(q, k, v, jnp.asarray(length))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_decode_ignores_stale_cache():
    """Rows past `length` must not influence the result."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(256, 2, 64)), jnp.float32)
    out1 = flash_decode(q, k, v, jnp.asarray(100))
    k2 = k.at[100:].set(999.0)
    v2 = v.at[100:].set(-999.0)
    out2 = flash_decode(q, k2, v2, jnp.asarray(100))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


def test_flash_decode_is_softmax_weighted_average():
    """With identical V rows the output equals that row, any mask."""
    q = jnp.ones((2, 32))
    k = jnp.asarray(np.random.default_rng(1).normal(size=(128, 1, 32)),
                    jnp.float32)
    v = jnp.broadcast_to(jnp.arange(32, dtype=jnp.float32), (128, 1, 32))
    out = flash_decode(q, k, v, jnp.asarray(77))
    np.testing.assert_allclose(np.asarray(out),
                               np.broadcast_to(np.arange(32), (2, 32)),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# kernel-in-model integration: GNN layer with use_kernel=True
# ---------------------------------------------------------------------------
def test_gnn_layer_kernel_path_matches_jnp_path():
    from repro.gnn.layers import aggregate_mean
    rng = np.random.default_rng(3)
    n, f, e = 60, 24, 200
    h = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(np.sort(rng.integers(0, n, e)), jnp.int32)
    w = jnp.asarray(rng.random(e), jnp.float32)
    deg = jnp.asarray(np.bincount(np.asarray(dst), weights=None,
                                  minlength=n), jnp.float32)
    a = aggregate_mean(h, src, dst, w, deg, use_kernel=False)
    b = aggregate_mean(h, src, dst, w, deg, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5,
                               atol=3e-5)


def _tiny_partition_setup(use_kernel, dropout=0.0):
    import dataclasses
    from repro.core import (make_arxiv_like, leiden_fusion,
                            build_partition_batch)
    from repro.gnn import GNNConfig, gather_partition_tensors
    ds = make_arxiv_like(n=250, feature_dim=8, num_classes=4, seed=9)
    labels = leiden_fusion(ds.graph, 2, alpha=0.3)
    batch = build_partition_batch(ds.graph, labels, scheme="repli")
    pt = gather_partition_tensors(ds, batch)
    tensors = {k: jnp.asarray(v) for k, v in {
        "features": pt.features, "labels": pt.labels,
        "train_mask": pt.train_mask, "edge_src": pt.edge_src,
        "edge_dst": pt.edge_dst, "edge_weight": pt.edge_weight,
        "in_degree": pt.in_degree, "node_mask": pt.node_mask}.items()}
    cfg = GNNConfig(kind="gcn", feature_dim=8, hidden_dim=16, embed_dim=16,
                    num_layers=2, dropout=dropout, use_kernel=use_kernel)
    return ds, batch, cfg, tensors


def test_local_train_step_with_kernel_runs_and_matches_jnp():
    """Regression anchor: one ``make_local_train_step`` step with
    ``use_kernel=True`` must run (this used to die in a bare AssertionError
    — the kernel had no VJP) and produce the jnp path's loss, grads, and
    updated params. Grads are cross-checked twice: against the segment-sum
    path and against a central finite difference."""
    from repro.gnn import init_partition_models, make_local_train_step
    from repro.gnn.train import _loss_one
    from repro.optim import adamw_init
    results = {}
    for use_kernel in (False, True):
        ds, batch, cfg, tensors = _tiny_partition_setup(use_kernel)
        params = init_partition_models(jax.random.PRNGKey(0), cfg,
                                       ds.num_classes, batch.k)
        opt = jax.vmap(adamw_init)(params)
        step = jax.jit(make_local_train_step(cfg, False, lr=1e-2))
        keys = jax.random.split(jax.random.PRNGKey(1), batch.k)
        new_p, _, loss = step(params, opt, tensors, keys)
        t0 = jax.tree.map(lambda x: x[0], tensors)
        p0 = jax.tree.map(lambda x: x[0], params)
        grads = jax.grad(_loss_one)(p0, cfg, t0, False, None)
        results[use_kernel] = (np.asarray(loss), new_p, grads, p0, t0, cfg)
    loss_j, p_j, g_j = results[False][:3]
    loss_k, p_k, g_k, p0, t0, cfg_k = results[True]
    np.testing.assert_allclose(loss_k, loss_j, rtol=1e-4, atol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4), g_k, g_j)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4), p_k, p_j)
    # finite-difference probe of the kernel-path gradient: perturb the first
    # GNN layer's weight matrix along a random direction
    rng = np.random.default_rng(2)
    d = rng.normal(size=np.asarray(p0["body"]["layers"][0]["w"]).shape)
    d = jnp.asarray(d / np.linalg.norm(d), jnp.float32)
    eps = 3e-2

    def at(t):
        p = jax.tree.map(lambda x: x, p0)
        p["body"]["layers"][0] = dict(p["body"]["layers"][0],
                                      w=p0["body"]["layers"][0]["w"] + t * d)
        return float(_loss_one(p, cfg_k, t0, False, None))

    fd = (at(eps) - at(-eps)) / (2 * eps)
    analytic = float(jnp.vdot(g_k["body"]["layers"][0]["w"], d))
    np.testing.assert_allclose(fd, analytic, rtol=5e-2, atol=5e-3)


def test_serve_step_flash_decode_matches_jnp_path():
    """cfg.use_flash_decode routes decode attention through the Pallas
    kernel; logits must match the jnp path."""
    import dataclasses
    import jax
    from repro.configs import get_config
    from repro.models import init_cache, init_model, serve_step
    cfg = get_config("qwen3_4b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    cfgk = dataclasses.replace(cfg, use_flash_decode=True)
    tok = jnp.ones((2, 1), jnp.int32)
    lengths = jnp.asarray([5, 9], jnp.int32)
    cache = init_cache(cfg, 2, 64)
    # fill the cache with noise so the mask matters
    cache = jax.tree.map(
        lambda x: jnp.asarray(np.random.default_rng(0).normal(
            0, 0.1, x.shape), x.dtype), cache)
    l1, _ = serve_step(params, cfg, tok, cache, lengths)
    l2, _ = serve_step(params, cfgk, tok, cache, lengths)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-3, atol=2e-3)
