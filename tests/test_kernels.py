"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the kernel bodies on CPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import (csr_aggregate, csr_aggregate_ref, flash_decode,
                           flash_decode_ref)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# csr_aggregate
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,f,e", [
    (8, 16, 32),          # tiny
    (100, 50, 700),       # unaligned everything
    (256, 128, 1024),     # exactly aligned
    (513, 130, 1500),     # off-by-one over tiles
    (64, 384, 256),       # multiple feature tiles
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_csr_aggregate_sweep(n, f, e, dtype):
    rng = np.random.default_rng(n * 7 + f)
    h = jnp.asarray(rng.normal(size=(n, f)), dtype)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(np.sort(rng.integers(0, n, e)), jnp.int32)
    w = jnp.asarray(rng.random(e), jnp.float32)
    out = csr_aggregate(h, src, dst, w, num_nodes=n)
    ref = csr_aggregate_ref(h, src, dst, w, num_nodes=n)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_csr_aggregate_zero_weight_edges_are_noops():
    h = jnp.ones((16, 8))
    src = jnp.zeros((10,), jnp.int32)
    dst = jnp.arange(10, dtype=jnp.int32)
    w = jnp.zeros((10,))
    out = csr_aggregate(h, src, dst, w, num_nodes=16)
    assert float(jnp.abs(out).max()) == 0.0


def test_csr_aggregate_duplicate_destinations_accumulate():
    h = jnp.eye(4, 8)
    src = jnp.asarray([0, 1, 2, 3], jnp.int32)
    dst = jnp.zeros((4,), jnp.int32)     # everything lands on row 0
    w = jnp.ones((4,))
    out = csr_aggregate(h, src, dst, w, num_nodes=4)
    np.testing.assert_allclose(np.asarray(out[0, :4]), np.ones(4), rtol=1e-6)
    assert float(jnp.abs(out[1:]).max()) == 0.0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.integers(4, 80), f=st.integers(1, 70), e=st.integers(1, 300))
def test_csr_aggregate_property(seed, n, f, e):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)  # unsorted is fine
    w = jnp.asarray(rng.random(e), jnp.float32)
    out = csr_aggregate(h, src, dst, w, num_nodes=n)
    ref = csr_aggregate_ref(h, src, dst, w, num_nodes=n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("hq,hkv,d,s,length", [
    (8, 8, 64, 600, 600),      # MHA, full cache
    (8, 2, 64, 1000, 777),     # GQA 4:1, partial
    (16, 1, 128, 2048, 1),     # MQA, single valid token
    (4, 4, 128, 512, 512),     # aligned block boundary
    (32, 8, 128, 1537, 1111),  # odd cache length
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(hq, hkv, d, s, length, dtype):
    rng = np.random.default_rng(hq * 131 + s)
    q = jnp.asarray(rng.normal(size=(hq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(s, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(s, hkv, d)), dtype)
    out = flash_decode(q, k, v, jnp.asarray(length))
    ref = flash_decode_ref(q, k, v, jnp.asarray(length))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_decode_ignores_stale_cache():
    """Rows past `length` must not influence the result."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(256, 2, 64)), jnp.float32)
    out1 = flash_decode(q, k, v, jnp.asarray(100))
    k2 = k.at[100:].set(999.0)
    v2 = v.at[100:].set(-999.0)
    out2 = flash_decode(q, k2, v2, jnp.asarray(100))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


def test_flash_decode_is_softmax_weighted_average():
    """With identical V rows the output equals that row, any mask."""
    q = jnp.ones((2, 32))
    k = jnp.asarray(np.random.default_rng(1).normal(size=(128, 1, 32)),
                    jnp.float32)
    v = jnp.broadcast_to(jnp.arange(32, dtype=jnp.float32), (128, 1, 32))
    out = flash_decode(q, k, v, jnp.asarray(77))
    np.testing.assert_allclose(np.asarray(out),
                               np.broadcast_to(np.arange(32), (2, 32)),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# kernel-in-model integration: GNN layer with use_kernel=True
# ---------------------------------------------------------------------------
def test_gnn_layer_kernel_path_matches_jnp_path():
    from repro.gnn.layers import aggregate_mean
    rng = np.random.default_rng(3)
    n, f, e = 60, 24, 200
    h = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(np.sort(rng.integers(0, n, e)), jnp.int32)
    w = jnp.asarray(rng.random(e), jnp.float32)
    deg = jnp.asarray(np.bincount(np.asarray(dst), weights=None,
                                  minlength=n), jnp.float32)
    a = aggregate_mean(h, src, dst, w, deg, use_kernel=False)
    b = aggregate_mean(h, src, dst, w, deg, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5,
                               atol=3e-5)


def test_serve_step_flash_decode_matches_jnp_path():
    """cfg.use_flash_decode routes decode attention through the Pallas
    kernel; logits must match the jnp path."""
    import dataclasses
    import jax
    from repro.configs import get_config
    from repro.models import init_cache, init_model, serve_step
    cfg = get_config("qwen3_4b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    cfgk = dataclasses.replace(cfg, use_flash_decode=True)
    tok = jnp.ones((2, 1), jnp.int32)
    lengths = jnp.asarray([5, 9], jnp.int32)
    cache = init_cache(cfg, 2, 64)
    # fill the cache with noise so the mask matters
    cache = jax.tree.map(
        lambda x: jnp.asarray(np.random.default_rng(0).normal(
            0, 0.1, x.shape), x.dtype), cache)
    l1, _ = serve_step(params, cfg, tok, cache, lengths)
    l2, _ = serve_step(params, cfgk, tok, cache, lengths)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-3, atol=2e-3)
