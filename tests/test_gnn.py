"""Tests for the GNN stack: layers, assembly, local training, pooling."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (make_arxiv_like, make_proteins_like, leiden_fusion,
                        build_partition_batch, build_halo_exchange)
from repro.gnn import (GNNConfig, train_local, train_classifier,
                       gather_partition_tensors, init_partition_models,
                       make_local_train_step, compute_embeddings,
                       pool_embeddings, mean_rocauc)
from repro.gnn.layers import aggregate_mean
from repro.optim import adamw_init


@pytest.fixture(scope="module")
def small_ds():
    return make_arxiv_like(n=600, feature_dim=16, num_classes=5, seed=7)


@pytest.fixture(scope="module")
def small_batch(small_ds):
    labels = leiden_fusion(small_ds.graph, 2, alpha=0.3)
    return labels, build_partition_batch(small_ds.graph, labels, scheme="repli")


# ---------------------------------------------------------------------------
# Assembly
# ---------------------------------------------------------------------------
def test_inner_batch_has_only_intra_edges(small_ds):
    labels = leiden_fusion(small_ds.graph, 2, alpha=0.3)
    b = build_partition_batch(small_ds.graph, labels, scheme="inner")
    for p in range(b.k):
        ids = b.node_ids[p]
        w = b.edge_weight[p]
        real = w > 0
        # every real edge connects two nodes of partition p
        assert (labels[ids[b.edge_src[p][real]]] == p).all()
        assert (labels[ids[b.edge_dst[p][real]]] == p).all()


def test_repli_halo_is_foreign_and_inbound_only(small_ds, small_batch):
    labels, b = small_batch
    for p in range(b.k):
        valid = b.node_mask[p]
        halo = valid & ~b.owned_mask[p]
        ids = b.node_ids[p]
        if halo.any():
            assert (labels[ids[halo]] != p).all()
        # arcs only point INTO owned nodes (halo rows are never destinations)
        real = b.edge_weight[p] > 0
        dst_rows = b.edge_dst[p][real]
        assert b.owned_mask[p][dst_rows].all()


def test_in_degree_matches_edges(small_ds, small_batch):
    _, b = small_batch
    for p in range(b.k):
        real = b.edge_weight[p] > 0
        counts = np.bincount(b.edge_dst[p][real], minlength=b.n_pad)
        assert (b.in_degree[p] == counts).all()


def test_every_node_owned_exactly_once(small_ds, small_batch):
    _, b = small_batch
    owned_ids = np.concatenate(
        [b.node_ids[p][b.owned_mask[p]] for p in range(b.k)])
    assert sorted(owned_ids.tolist()) == list(range(small_ds.graph.n))


# ---------------------------------------------------------------------------
# Aggregation semantics
# ---------------------------------------------------------------------------
def test_aggregate_mean_matches_dense_reference():
    rng = np.random.default_rng(0)
    n, f = 10, 4
    h = rng.normal(size=(n, f)).astype(np.float32)
    src = np.array([0, 1, 2, 3, 0], dtype=np.int32)
    dst = np.array([1, 1, 3, 0, 3], dtype=np.int32)
    w = np.ones(5, dtype=np.float32)
    deg = np.bincount(dst, minlength=n).astype(np.float32)
    out = aggregate_mean(jnp.asarray(h), jnp.asarray(src), jnp.asarray(dst),
                         jnp.asarray(w), jnp.asarray(deg))
    # dense adjacency reference
    A = np.zeros((n, n), dtype=np.float32)
    for s, d in zip(src, dst):
        A[d, s] += 1
    ref = A @ h / np.maximum(deg[:, None], 1.0)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5)


def test_padding_arcs_are_noops():
    h = jnp.ones((8, 3))
    src = jnp.zeros((6,), jnp.int32)
    dst = jnp.full((6,), 7, jnp.int32)   # parked at last row
    w = jnp.zeros((6,))
    deg = jnp.zeros((8,))
    out = aggregate_mean(h, src, dst, w, deg)
    assert jnp.allclose(out, 0.0)


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["gcn", "sage"])
def test_local_training_reduces_loss(small_ds, small_batch, kind):
    labels, b = small_batch
    cfg = GNNConfig(kind=kind, feature_dim=16, hidden_dim=32, embed_dim=32,
                    num_layers=2, dropout=0.0)
    pt = gather_partition_tensors(small_ds, b)
    params = init_partition_models(jax.random.PRNGKey(0), cfg,
                                   small_ds.num_classes, b.k)
    opt = jax.vmap(adamw_init)(params)
    tensors = {k: jnp.asarray(v) for k, v in {
        "features": pt.features, "labels": pt.labels,
        "train_mask": pt.train_mask, "edge_src": pt.edge_src,
        "edge_dst": pt.edge_dst, "edge_weight": pt.edge_weight,
        "in_degree": pt.in_degree, "node_mask": pt.node_mask}.items()}
    step = jax.jit(make_local_train_step(cfg, False, lr=1e-2))
    keys = jax.random.split(jax.random.PRNGKey(1), b.k)
    _, _, loss0 = step(params, opt, tensors, keys)
    p, o = params, opt
    for i in range(25):
        p, o, loss = step(p, o, tensors, keys)
    assert float(loss.mean()) < float(loss0.mean()) * 0.7
    assert np.isfinite(float(loss.mean()))


def test_train_local_end_to_end_beats_random_partition(small_ds):
    from repro.core import random_partition
    cfg = GNNConfig(kind="gcn", feature_dim=16, hidden_dim=32, embed_dim=32,
                    num_layers=2, dropout=0.0)
    acc = {}
    for name, lab in (("lf", leiden_fusion(small_ds.graph, 2, alpha=0.3)),
                      ("rnd", random_partition(small_ds.graph, 2))):
        b = build_partition_batch(small_ds.graph, lab, scheme="inner")
        _, emb = train_local(small_ds, b, cfg, epochs=30, lr=1e-2)
        acc[name] = train_classifier(small_ds, emb, epochs=80)["test"]
    assert acc["lf"] > acc["rnd"] + 0.05   # structural integrity matters


def test_pool_embeddings_places_owned_rows(small_ds, small_batch):
    _, b = small_batch
    pt = gather_partition_tensors(small_ds, b)
    k, n_pad = b.k, b.n_pad
    emb = np.zeros((k, n_pad, 2), dtype=np.float32)
    for p in range(k):
        emb[p, :, 0] = p + 1
        emb[p, :, 1] = np.arange(n_pad)
    out = pool_embeddings(emb, pt, small_ds.graph.n, 2)
    for p in range(k):
        owned_rows = np.where(b.owned_mask[p])[0]
        ids = b.node_ids[p][owned_rows]
        assert (out[ids, 0] == p + 1).all()
        assert (out[ids, 1] == owned_rows).all()


def test_multilabel_pipeline_and_rocauc():
    ds = make_proteins_like(n=400, num_tasks=6, seed=2)
    lab = leiden_fusion(ds.graph, 2, alpha=0.3)
    b = build_partition_batch(ds.graph, lab, scheme="inner")
    cfg = GNNConfig(kind="sage", feature_dim=ds.features.shape[1],
                    hidden_dim=16, embed_dim=16, num_layers=2, dropout=0.0)
    _, emb = train_local(ds, b, cfg, epochs=20, lr=1e-2)
    res = train_classifier(ds, emb, epochs=50)
    assert 0.0 <= res["test"] <= 1.0
    assert res["train"] > 0.5   # learned something


def test_rocauc_perfect_and_random():
    y = np.array([[1], [1], [0], [0]], dtype=np.float32)
    s_perfect = np.array([[0.9], [0.8], [0.2], [0.1]])
    s_inverted = -s_perfect
    assert mean_rocauc(y, s_perfect) == 1.0
    assert mean_rocauc(y, s_inverted) == 0.0
