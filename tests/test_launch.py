"""Launch-layer tests on the local (1-device) mesh: build() lowers and
compiles for every step kind with reduced configs; sharding API contracts.

The production 256/512-device behaviour is covered by the dry-run artifacts
(benchmarks/artifacts/dryrun) — here we pin the machinery itself."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.launch.steps import build, params_spec
from repro.models.inputs import InputShape


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


SMALL = {
    "train": InputShape("train_small", 128, 2, "train"),
    "prefill": InputShape("prefill_small", 128, 2, "prefill"),
    "decode": InputShape("decode_small", 256, 2, "decode"),
}


@pytest.mark.parametrize("arch", ["qwen3_4b", "qwen2_moe_a2p7b",
                                  "xlstm_125m", "seamless_m4t_large_v2"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_build_lowers_and_compiles(mesh, arch, kind):
    cfg = get_config(arch).reduced()
    shape_name = {"train": "train_4k", "prefill": "prefill_32k",
                  "decode": "decode_32k"}[kind]
    with mesh:
        fn, sds = build(cfg, shape_name, mesh, shape_override=SMALL[kind])
        compiled = fn.lower(*sds).compile()
    assert compiled.cost_analysis() is not None


def test_build_executes_train_step(mesh):
    """The same build() artifact must run with real arrays (not only SDS)."""
    from repro.models import init_model, make_batch
    from repro.optim import adamw_init
    cfg = get_config("qwen3_4b").reduced()
    with mesh:
        fn, sds = build(cfg, "train_4k", mesh, shape_override=SMALL["train"])
        params = init_model(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        batch = make_batch(cfg, batch=2, seq=128)
        p2, o2, loss = fn(params, opt, batch)
    assert np.isfinite(float(loss))


def test_mode_validation():
    from repro.launch.sharding import MODES, _mode_axes
    m = jax.make_mesh((1, 1), ("data", "model"))
    for mode in MODES:
        _mode_axes(m, mode)
    with pytest.raises(AssertionError):
        _mode_axes(m, "nonsense")


def test_cache_shardings_long_context_seq_sharded():
    """long_500k (batch 1 < data axis): cache must shard SEQUENCE over data,
    not batch. Needs a multi-device mesh -> subprocess with 4 host devices."""
    from tests.test_distributed_gnn import run_with_devices
    out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.launch.sharding import cache_shardings
mesh = jax.make_mesh((2, 2), ("data", "model"))
cache = {"layers": {"k": jax.ShapeDtypeStruct((2, 1, 1024, 8, 64),
                                              jnp.bfloat16)}}
sh = cache_shardings(mesh, cache, global_batch=1)
spec = sh["layers"]["k"].spec
print("SPEC:", spec[1], "|", spec[2])
""")
    assert "SPEC: None | data" in out    # batch unsharded, seq over data


def test_depth_pair_respects_block_pattern():
    import sys
    sys.modules.pop("repro.launch.dryrun", None)
    import os
    os.environ.setdefault("XLA_FLAGS", "")
    from repro.launch import dryrun
    zp = get_config("zamba2_1p2b")
    l1, l2 = dryrun._depth_pair(zp)
    assert l1 == 6 and l2 == 12          # one / two pattern periods
    ds = get_config("deepseek_v2_236b")
    l1, l2 = dryrun._depth_pair(ds)
    assert l1 == 2 and l2 == 3           # first_k_dense=1 + 1/2 MoE layers


def test_effective_config_long500k_variants():
    from repro.models import effective_config
    dense = effective_config(get_config("qwen3_4b"), "long_500k")
    assert dense.attention == "sliding"
    ssm = effective_config(get_config("xlstm_125m"), "long_500k")
    assert ssm.attention == "full"       # untouched: no attn blocks
    hyb = effective_config(get_config("zamba2_1p2b"), "long_500k")
    assert hyb.attention == "sliding"    # shared attn blocks get the window
