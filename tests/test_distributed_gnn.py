"""Distributed-runtime tests: run in a subprocess with 4 fake host devices
(XLA_FLAGS must be set before jax initializes, so these can't run in-process
— the main test session keeps 1 device per the project convention)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


PREAMBLE = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import (make_arxiv_like, leiden_fusion, build_partition_batch,
                        build_halo_exchange)
from repro.gnn import (GNNConfig, gather_partition_tensors,
                       init_partition_models, make_local_train_step,
                       make_sync_train_step)
from repro.optim import adamw_init

ds = make_arxiv_like(n=400, feature_dim=8, num_classes=4, seed=3)
labels = leiden_fusion(ds.graph, 4, alpha=0.3)
batch = build_partition_batch(ds.graph, labels, scheme="repli")
pt = gather_partition_tensors(ds, batch)
cfg = GNNConfig(kind="gcn", feature_dim=8, hidden_dim=16, embed_dim=16,
                num_layers=2, dropout=0.0)
params = init_partition_models(jax.random.PRNGKey(0), cfg, 4, 4)
opt = jax.vmap(adamw_init)(params)
tensors = {k: jnp.asarray(v) for k, v in {
    'features': pt.features, 'labels': pt.labels,
    'train_mask': pt.train_mask, 'edge_src': pt.edge_src,
    'edge_dst': pt.edge_dst, 'edge_weight': pt.edge_weight,
    'in_degree': pt.in_degree, 'node_mask': pt.node_mask}.items()}
mesh = jax.make_mesh((4,), ("data",))
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
"""


def test_local_step_has_zero_collectives():
    """THE paper claim, checked mechanically: LF local training lowers to an
    HLO with no communication ops at all."""
    out = run_with_devices(PREAMBLE + """
shard = NamedSharding(mesh, P("data"))
step = jax.jit(make_local_train_step(cfg, False, lr=1e-2),
               in_shardings=(shard, shard, shard, shard),
               out_shardings=(shard, shard, shard))
keys = jax.random.split(jax.random.PRNGKey(1), 4)
lowered = step.lower(params, opt, tensors, keys)
hlo = lowered.compile().as_text()
found = [c for c in COLLECTIVES if c in hlo]
print("COLLECTIVES:", found)
p2, o2, loss = step(params, opt, tensors, keys)
print("LOSS_FINITE:", bool(jnp.isfinite(loss).all()))
""")
    assert "COLLECTIVES: []" in out
    assert "LOSS_FINITE: True" in out


def test_sync_step_communicates_and_trains():
    """The synchronized baseline must contain an all-gather (halo exchange)
    and still reduce the loss."""
    out = run_with_devices(PREAMBLE + """
halo = build_halo_exchange(ds.graph, labels, batch)
step = make_sync_train_step(cfg, halo, False, mesh, lr=1e-2)
keys = jax.random.split(jax.random.PRNGKey(1), 4)
hlo = step.lower(params, opt, tensors, keys).compile().as_text()
has_comm = any(c in hlo for c in COLLECTIVES)
print("HAS_COMM:", has_comm)
p, o = params, opt
for i in range(15):
    p, o, loss = step(p, o, tensors, keys)
    if i == 0:
        first = float(loss.mean())
print("IMPROVED:", float(loss.mean()) < first)
print("FINITE:", bool(jnp.isfinite(loss).all()))
""")
    assert "HAS_COMM: True" in out
    assert "IMPROVED: True" in out
    assert "FINITE: True" in out


def test_sync_step_consumes_dropout_like_local():
    """Both modes must consume the training config identically: with
    cfg.dropout > 0 the sync step's loss depends on the dropout key (the
    old code silently trained the baseline with no dropout), and with
    dropout == 0 the key is inert."""
    out = run_with_devices(PREAMBLE + """
import dataclasses
halo = build_halo_exchange(ds.graph, labels, batch)
ka = jax.random.split(jax.random.PRNGKey(1), 4)
kb = jax.random.split(jax.random.PRNGKey(2), 4)
cfg_d = dataclasses.replace(cfg, dropout=0.5)
step_d = make_sync_train_step(cfg_d, halo, False, mesh, lr=1e-2)
_, _, la = step_d(params, opt, tensors, ka)
_, _, la2 = step_d(params, opt, tensors, ka)
_, _, lb = step_d(params, opt, tensors, kb)
print("KEY_MATTERS:", bool(jnp.abs(la - lb).max() > 1e-6))
print("DETERMINISTIC:", bool(jnp.abs(la - la2).max() == 0.0))
step_0 = make_sync_train_step(cfg, halo, False, mesh, lr=1e-2)
_, _, za = step_0(params, opt, tensors, ka)
_, _, zb = step_0(params, opt, tensors, kb)
print("INERT_AT_ZERO:", bool(jnp.abs(za - zb).max() == 0.0))
""")
    assert "KEY_MATTERS: True" in out
    assert "DETERMINISTIC: True" in out
    assert "INERT_AT_ZERO: True" in out


def test_sync_step_trains_through_pallas_kernel():
    """use_kernel=True is a real path in sync mode too: the shard_map step
    (check_rep=False — pallas_call has no replication rule) lowers, still
    contains the halo all_gather, and at dropout=0 matches the jnp path's
    loss."""
    out = run_with_devices(PREAMBLE + """
import dataclasses
halo = build_halo_exchange(ds.graph, labels, batch)
keys = jax.random.split(jax.random.PRNGKey(1), 4)
cfg_k = dataclasses.replace(cfg, use_kernel=True)
step_k = make_sync_train_step(cfg_k, halo, False, mesh, lr=1e-2)
hlo = step_k.lower(params, opt, tensors, keys).compile().as_text()
print("HAS_COMM:", any(c in hlo for c in COLLECTIVES))
step_j = make_sync_train_step(cfg, halo, False, mesh, lr=1e-2)
_, _, lj = step_j(params, opt, tensors, keys)
_, _, lk = step_k(params, opt, tensors, keys)
print("MAXDIFF:", float(jnp.abs(lj - lk).max()))
""")
    assert "HAS_COMM: True" in out
    maxdiff = float(out.split("MAXDIFF:")[1].strip())
    assert maxdiff < 1e-4


def test_stale_steps_consume_dropout_like_sync():
    """The stale-mode steps thread the per-epoch dropout keys exactly like
    the other modes: with dropout > 0 both the exchange and the
    between-exchange (cached) step depend on the key and are deterministic
    under it; with dropout == 0 the key is inert."""
    out = run_with_devices(PREAMBLE + """
import dataclasses
from repro.gnn import make_stale_train_steps
halo = build_halo_exchange(ds.graph, labels, batch)
ka = jax.random.split(jax.random.PRNGKey(1), 4)
kb = jax.random.split(jax.random.PRNGKey(2), 4)
cfg_d = dataclasses.replace(cfg, dropout=0.5)
steps = make_stale_train_steps(cfg_d, halo, False, mesh, lr=1e-2)
_, _, la, caches = steps["exchange"](params, opt, tensors, ka)
_, _, lb, _ = steps["exchange"](params, opt, tensors, kb)
print("EX_KEY_MATTERS:", bool(jnp.abs(la - lb).max() > 1e-6))
_, _, sa = steps["stale"](params, opt, tensors, ka, caches)
_, _, sa2 = steps["stale"](params, opt, tensors, ka, caches)
_, _, sb = steps["stale"](params, opt, tensors, kb, caches)
print("ST_KEY_MATTERS:", bool(jnp.abs(sa - sb).max() > 1e-6))
print("ST_DETERMINISTIC:", bool(jnp.abs(sa - sa2).max() == 0.0))
steps0 = make_stale_train_steps(cfg, halo, False, mesh, lr=1e-2)
_, _, za, c0 = steps0["exchange"](params, opt, tensors, ka)
_, _, zb, _ = steps0["exchange"](params, opt, tensors, kb)
print("INERT_AT_ZERO:", bool(jnp.abs(za - zb).max() == 0.0))
""")
    assert "EX_KEY_MATTERS: True" in out
    assert "ST_KEY_MATTERS: True" in out
    assert "ST_DETERMINISTIC: True" in out
    assert "INERT_AT_ZERO: True" in out


def test_local_matches_single_device_numerics():
    """Sharding over 4 devices must be bit-compatible (up to float noise)
    with the unsharded vmap execution."""
    out = run_with_devices(PREAMBLE + """
step_fn = make_local_train_step(cfg, False, lr=1e-2)
keys = jax.random.split(jax.random.PRNGKey(1), 4)
shard = NamedSharding(mesh, P("data"))
step_sharded = jax.jit(step_fn, in_shardings=(shard, shard, shard, shard),
                       out_shardings=(shard, shard, shard))
step_plain = jax.jit(step_fn)
_, _, l1 = step_sharded(params, opt, tensors, keys)
_, _, l2 = step_plain(params, opt, tensors, keys)
print("MAXDIFF:", float(jnp.abs(l1 - l2).max()))
""")
    maxdiff = float(out.split("MAXDIFF:")[1].strip())
    assert maxdiff < 1e-5
