"""End-to-end behaviour tests for the paper's system: the full
partition -> local-train -> pool -> classify pipeline, and the CLI drivers."""
import json
import subprocess
import sys
import os

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_full_paper_pipeline_beats_raw_features():
    """LF + local GNN training + pooled classifier must clearly beat an MLP
    on raw features (the GNN aggregation is doing the work), while training
    each partition independently."""
    from repro.core import (build_partition_batch, evaluate_partition,
                            leiden_fusion, make_arxiv_like)
    from repro.gnn import GNNConfig, train_classifier, train_local
    ds = make_arxiv_like(n=1500, feature_dim=32, num_classes=8, seed=11)
    raw = train_classifier(ds, ds.features, epochs=80)

    labels = leiden_fusion(ds.graph, 4)
    rep = evaluate_partition(ds.graph, labels)
    assert rep.max_components == 1 and rep.total_isolated == 0
    batch = build_partition_batch(ds.graph, labels, scheme="repli")
    cfg = GNNConfig(kind="gcn", feature_dim=32, hidden_dim=48, embed_dim=48,
                    num_layers=3, dropout=0.2)
    _, emb = train_local(ds, batch, cfg, epochs=40, lr=5e-3)
    res = train_classifier(ds, emb, epochs=80)
    assert res["test"] > raw["test"] + 0.2, (res, raw)


def _run_cli(args, timeout=420):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, env=env, timeout=timeout, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_train_cli_gnn():
    out = _run_cli(["repro.launch.train", "--workload", "gnn",
                    "--nodes", "800", "--k", "2", "--epochs", "8",
                    "--hidden", "32"])
    rec = json.loads(out)
    assert rec["partition_quality"]["total_isolated"] == 0
    assert rec["partition_quality"]["max_components"] == 1
    assert 0 <= rec["results"]["test"] <= 1


def test_train_cli_lm():
    out = _run_cli(["repro.launch.train", "--workload", "lm",
                    "--arch", "xlstm_125m", "--reduced", "--steps", "4",
                    "--batch", "2", "--seq", "32"])
    rec = json.loads(out)
    assert rec["last_loss"] < rec["first_loss"]


def test_serve_cli():
    out = _run_cli(["repro.launch.serve", "--arch", "qwen3_4b", "--reduced",
                    "--requests", "2", "--max-new", "4",
                    "--max-prompt", "12"])
    rec = json.loads(out)
    assert rec["finite"] is True
    assert len(rec["sample_generation"]) >= 4


def test_checkpoint_roundtrip_via_cli(tmp_path):
    _run_cli(["repro.launch.train", "--workload", "lm", "--arch",
              "xlstm_125m", "--reduced", "--steps", "2", "--batch", "2",
              "--seq", "32", "--ckpt-dir", str(tmp_path)])
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 2
