"""stale(period=N) training mode + model integration (DESIGN.md §12).

The mode is pinned by its two exact limits — stale(1) IS the sync baseline
and stale(never) IS local training — plus the communication contract: the
exchange step moves exactly the sync bytes and the between-exchange step
lowers to ZERO collectives. Multi-device runtime tests run in a subprocess
with 4 fake host devices (same convention as test_distributed_gnn);
schedule/integration properties run in-process under hypothesis.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (INTEGRATION_KINDS, average_partition_params,
                        integrate_models)
from repro.gnn import (apply_integration, stale_bytes_per_epoch,
                       stale_exchange_epochs)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


PREAMBLE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import (make_arxiv_like, leiden_fusion, build_partition_batch,
                        build_halo_exchange)
from repro.gnn import GNNConfig, train_local, train_stale, train_sync

ds = make_arxiv_like(n=400, feature_dim=8, num_classes=4, seed=3)
labels = leiden_fusion(ds.graph, 4, alpha=0.3)
batch = build_partition_batch(ds.graph, labels, scheme="repli")
halo = build_halo_exchange(ds.graph, labels, batch)
cfg = GNNConfig(kind="gcn", feature_dim=8, hidden_dim=16, embed_dim=16,
                num_layers=2, dropout=0.0)
mesh = jax.make_mesh((4,), ("data",))

def maxdiff(a, b):
    pa, pb = jax.tree.leaves(a), jax.tree.leaves(b)
    return max(float(jnp.abs(x - y).max()) for x, y in zip(pa, pb))
"""


# ---------------------------------------------------------------------------
# the two exact limits, jnp aggregation path
# ---------------------------------------------------------------------------
def test_stale_period1_matches_sync():
    """sync_period=1 exchanges every epoch — it IS train_sync, parameter for
    parameter and embedding for embedding."""
    out = run_with_devices(PREAMBLE + """
p_sync, emb_sync = train_sync(ds, batch, halo, cfg, mesh, epochs=5, seed=0)
p_st, emb_st = train_stale(ds, batch, halo, cfg, mesh, epochs=5, seed=0,
                           sync_period=1)
print("PARAMS_MAXDIFF:", maxdiff(p_sync, p_st))
print("EMB_MAXDIFF:", float(np.abs(emb_sync - emb_st).max()))
""")
    assert float(out.split("PARAMS_MAXDIFF:")[1].split()[0]) == 0.0
    assert float(out.split("EMB_MAXDIFF:")[1].split()[0]) == 0.0


def test_stale_never_exchange_matches_local():
    """sync_period=0 never exchanges: stale training must reproduce
    train_local exactly — including through dropout, which exercises the
    shared per-epoch key schedule."""
    out = run_with_devices(PREAMBLE + """
import dataclasses
cfg_d = dataclasses.replace(cfg, dropout=0.3)
p_loc, emb_loc = train_local(ds, batch, cfg_d, epochs=5, seed=0, mesh=None)
p_st, emb_st = train_stale(ds, batch, halo, cfg_d, mesh, epochs=5, seed=0,
                           sync_period=0)
print("PARAMS_MAXDIFF:", maxdiff(p_loc, p_st))
print("EMB_MAXDIFF:", float(np.abs(emb_loc - emb_st).max()))
""")
    assert float(out.split("PARAMS_MAXDIFF:")[1].split()[0]) < 1e-6
    assert float(out.split("EMB_MAXDIFF:")[1].split()[0]) < 1e-6


# ---------------------------------------------------------------------------
# the same limits through the Pallas aggregation kernel
# ---------------------------------------------------------------------------
def test_stale_period1_matches_sync_with_kernel():
    out = run_with_devices(PREAMBLE + """
import dataclasses
cfg_k = dataclasses.replace(cfg, use_kernel=True)
p_sync, emb_sync = train_sync(ds, batch, halo, cfg_k, mesh, epochs=3, seed=0)
p_st, emb_st = train_stale(ds, batch, halo, cfg_k, mesh, epochs=3, seed=0,
                           sync_period=1)
print("PARAMS_MAXDIFF:", maxdiff(p_sync, p_st))
print("EMB_MAXDIFF:", float(np.abs(emb_sync - emb_st).max()))
""")
    assert float(out.split("PARAMS_MAXDIFF:")[1].split()[0]) < 1e-5
    assert float(out.split("EMB_MAXDIFF:")[1].split()[0]) < 1e-5


def test_stale_never_exchange_matches_local_with_kernel():
    out = run_with_devices(PREAMBLE + """
import dataclasses
cfg_k = dataclasses.replace(cfg, use_kernel=True)
p_loc, emb_loc = train_local(ds, batch, cfg_k, epochs=3, seed=0, mesh=None)
p_st, emb_st = train_stale(ds, batch, halo, cfg_k, mesh, epochs=3, seed=0,
                           sync_period=0)
print("PARAMS_MAXDIFF:", maxdiff(p_loc, p_st))
print("EMB_MAXDIFF:", float(np.abs(emb_loc - emb_st).max()))
""")
    assert float(out.split("PARAMS_MAXDIFF:")[1].split()[0]) < 1e-5
    assert float(out.split("EMB_MAXDIFF:")[1].split()[0]) < 1e-5


# ---------------------------------------------------------------------------
# the communication contract
# ---------------------------------------------------------------------------
def test_stale_exchange_bytes_match_sync_and_stale_step_is_collective_free():
    """The exchange step moves exactly the sync bytes; the between-exchange
    step lowers to an HLO with zero collective bytes."""
    out = run_with_devices(PREAMBLE + """
from repro.launch.hlo_analysis import collective_bytes
hlo_sync, hlo_st = {}, {}
train_sync(ds, batch, halo, cfg, mesh, epochs=2, seed=0, hlo_out=hlo_sync)
train_stale(ds, batch, halo, cfg, mesh, epochs=4, seed=0, sync_period=2,
            hlo_out=hlo_st)
b_sync = collective_bytes(hlo_sync["hlo"])["total"]
b_ex = collective_bytes(hlo_st["hlo"])["total"]
b_between = collective_bytes(hlo_st["hlo_stale"])["total"]
print("SYNC_BYTES:", b_sync)
print("EXCHANGE_MATCHES:", b_ex == b_sync and b_sync > 0)
print("BETWEEN_BYTES:", b_between)
""")
    assert "EXCHANGE_MATCHES: True" in out
    assert int(out.split("BETWEEN_BYTES:")[1].split()[0]) == 0


def test_stale_pipeline_records_schedule_and_is_deterministic():
    """End to end through the Pipeline: the report carries sync_period, the
    per-epoch average sits strictly below the per-step bytes, the stale step
    is collective-free — and two identical runs emit identical reports."""
    out = run_with_devices("""
import json
from repro.pipeline import Pipeline, PipelineConfig

def run_once():
    cfg = PipelineConfig(dataset="karate", method="leiden_fusion", k=4,
                         seed=0, scheme="repli", mode="stale", sync_period=3,
                         integrate="model_avg", hidden_dim=16, embed_dim=16,
                         num_layers=2, dropout=0.0, epochs=6,
                         classifier_epochs=20, cache_dir=None)
    return Pipeline(cfg).run()

ra, rb = run_once(), run_once()
da, db = ra.as_dict(), rb.as_dict()
print("SYNC_PERIOD:", da["config"]["sync_period"])
print("INTEGRATE:", da["config"]["integrate"])
c = ra.collectives
print("AVG_BELOW_STEP:", 0 < c["per_epoch_avg"] < c["total"])
print("STALE_STEP_BYTES:", c["stale_step_total"])
print("N_EXCHANGE:", c["n_exchange_epochs"])
same = (da["accuracy"] == db["accuracy"] and
        da["collectives"] == db["collectives"])
print("DETERMINISTIC:", same)
print("SUMMARY_HAS_MODE:", "mode=stale(period=3)" in ra.summary())
""")
    assert "SYNC_PERIOD: 3" in out
    assert "INTEGRATE: model_avg" in out
    assert "AVG_BELOW_STEP: True" in out
    assert int(out.split("STALE_STEP_BYTES:")[1].split()[0]) == 0
    assert int(out.split("N_EXCHANGE:")[1].split()[0]) == 2
    assert "DETERMINISTIC: True" in out
    assert "SUMMARY_HAS_MODE: True" in out


# ---------------------------------------------------------------------------
# exchange schedule — in-process, pure python
# ---------------------------------------------------------------------------
def test_exchange_epochs_period1_is_every_epoch():
    assert stale_exchange_epochs(5, 1) == [0, 1, 2, 3, 4]


def test_exchange_epochs_never_and_oversized_period():
    assert stale_exchange_epochs(5, 0) == []
    assert stale_exchange_epochs(5, None) == []
    # a period longer than training still exchanges once, at epoch 0
    assert stale_exchange_epochs(5, 100) == [0]


def test_bytes_per_epoch_example():
    assert stale_bytes_per_epoch(10, 6, 2) == [10, 0, 10, 0, 10, 0]
    assert stale_bytes_per_epoch(10, 4, 0) == [0, 0, 0, 0]


@settings(max_examples=30, deadline=None)
@given(epochs=st.integers(min_value=1, max_value=40),
       period=st.integers(min_value=0, max_value=8),
       nbytes=st.integers(min_value=1, max_value=10**9))
def test_bytes_per_epoch_zero_exactly_off_schedule(epochs, period, nbytes):
    """Collective bytes are exactly 0 between exchange epochs and exactly
    the exchange bytes on them."""
    per = stale_bytes_per_epoch(nbytes, epochs, period)
    on = set(stale_exchange_epochs(epochs, period))
    assert len(per) == epochs
    for e, b in enumerate(per):
        assert b == (nbytes if e in on else 0)
    if period >= 1:
        assert 0 in on              # epoch 0 always exchanges


@settings(max_examples=30, deadline=None)
@given(epochs=st.integers(min_value=1, max_value=40),
       nbytes=st.integers(min_value=1, max_value=10**9))
def test_bytes_per_epoch_monotone_in_period(epochs, nbytes):
    """Total (and so average) collective bytes are monotone non-increasing
    as the period grows, from the sync pole down to local's zero."""
    totals = [sum(stale_bytes_per_epoch(nbytes, epochs, p))
              for p in range(1, epochs + 2)]
    assert all(a >= b for a, b in zip(totals, totals[1:]))
    assert totals[0] == nbytes * epochs                  # period=1 == sync
    assert sum(stale_bytes_per_epoch(nbytes, epochs, 0)) == 0   # local pole


# ---------------------------------------------------------------------------
# model integration — in-process
# ---------------------------------------------------------------------------
def _stacked_params(k: int, seed: int):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(k, 5, 3)).astype(np.float32),
            "layers": [{"b": rng.normal(size=(k, 7)).astype(np.float32)}]}


@settings(max_examples=10, deadline=None)
@given(k=st.integers(min_value=2, max_value=4),
       seed=st.integers(min_value=0, max_value=10**6))
def test_model_avg_of_identical_models_is_fixed_point(k, seed):
    import jax
    rng = np.random.default_rng(seed)
    one = {"w": rng.normal(size=(1, 5, 3)).astype(np.float32)}
    params = jax.tree.map(lambda x: np.broadcast_to(x, (k,) + x.shape[1:]),
                          one)
    avg = average_partition_params(params)
    for a, b in zip(jax.tree.leaves(avg), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_model_avg_is_mean_and_keeps_shape():
    import jax
    params = _stacked_params(3, seed=7)
    avg = average_partition_params(params)
    for a, x in zip(jax.tree.leaves(avg), jax.tree.leaves(params)):
        a, x = np.asarray(a), np.asarray(x)
        assert a.shape == x.shape
        expect = x.mean(axis=0)
        for row in a:
            np.testing.assert_allclose(row, expect, atol=1e-6)


def test_model_avg_weighted_selects_row():
    import jax
    params = _stacked_params(3, seed=11)
    picked = average_partition_params(params, weights=np.array([0., 1., 0.]))
    for a, x in zip(jax.tree.leaves(picked), jax.tree.leaves(params)):
        a, x = np.asarray(a), np.asarray(x)
        for row in a:
            np.testing.assert_allclose(row, x[1], atol=1e-6)


def test_integrate_models_validates_kind():
    params = _stacked_params(2, seed=0)
    with pytest.raises(ValueError, match="integration kind"):
        integrate_models(params, kind="bogus")
    with pytest.raises(ValueError, match="prediction-level"):
        integrate_models(params, kind="ensemble")
    assert integrate_models(params, kind="none") is params
    assert "none" in INTEGRATION_KINDS and "model_avg" in INTEGRATION_KINDS


def test_apply_integration_ensemble_of_identical_models_matches_single():
    """Prediction-level ensembling of k identical models must equal any
    single model's embeddings — and model_avg must agree too."""
    import jax
    import jax.numpy as jnp
    k = 3
    one = np.random.default_rng(5).normal(size=(1, 4, 4)).astype(np.float32)
    params = {"w": jnp.asarray(np.broadcast_to(one, (k, 4, 4)))}
    emb_fn = lambda p: np.asarray(p["w"]).reshape(k, -1) * 2.0
    base = emb_fn(params)
    for kind in ("ensemble", "model_avg", "none"):
        p2, emb = apply_integration(params, kind, emb_fn, k)
        np.testing.assert_allclose(emb, base, atol=1e-5)
    with pytest.raises(ValueError):
        apply_integration(params, "bogus", emb_fn, k)


def test_pipeline_rejects_bad_integrate_and_period():
    from repro.pipeline import Pipeline, PipelineConfig
    with pytest.raises(ValueError, match="integrat"):
        Pipeline(PipelineConfig(dataset="karate", k=2, integrate="bogus",
                                epochs=1, classifier_epochs=0)).run()
    with pytest.raises(ValueError, match="sync_period"):
        Pipeline(PipelineConfig(dataset="karate", k=2, mode="stale",
                                sync_period=-1, epochs=1,
                                classifier_epochs=0)).run()


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=3))
def test_pipeline_report_deterministic_for_fixed_seed(seed):
    """Same config + seed -> byte-identical accuracy and collectives
    (single-device mode=local run; the stale-mode determinism twin runs in
    the subprocess test above)."""
    from repro.pipeline import Pipeline, PipelineConfig
    cfg = PipelineConfig(dataset="karate", method="leiden_fusion", k=2,
                         seed=seed, mode="local", hidden_dim=8, embed_dim=8,
                         num_layers=2, epochs=2, classifier_epochs=5,
                         cache_dir=None, collect_hlo=False)
    ra = Pipeline(cfg).run().as_dict()
    rb = Pipeline(cfg).run().as_dict()
    assert ra["accuracy"] == rb["accuracy"]
    assert ra["collectives"] == rb["collectives"]
