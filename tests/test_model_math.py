"""Mathematical invariants of the model components:
- chunked attention == naive full attention
- chunked linear attention == sequential recurrence (any chunk size)
- MoE sort-based dispatch == dense per-token expert evaluation
- RoPE preserves norms and relative positions
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import _attend_chunked
from repro.models.ssm import chunked_linear_attention, linear_attention_step


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def _naive_attention(q, k, v, causal, window=None):
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(dh)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("sq,causal,window,g", [
    (64, True, None, 1),
    (300, True, None, 2),     # uneven chunks (Q_CHUNK=512 > sq: single)
    (600, True, None, 4),     # crosses a chunk boundary
    (600, False, None, 1),
    (600, True, 128, 2),      # sliding window
])
def test_chunked_attention_matches_naive(sq, causal, window, g):
    rng = np.random.default_rng(sq + g)
    b, hkv, dh = 2, 2, 16
    h = hkv * g
    q = jnp.asarray(rng.normal(size=(b, sq, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sq, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sq, hkv, dh)), jnp.float32)
    out = _attend_chunked(q, k, v, causal, window)
    ref = _naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_chunked_attention_unroll_identical():
    rng = np.random.default_rng(0)
    b, s, h, dh = 1, 600, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    a = _attend_chunked(q, k, v, True, None, unroll=False)
    c = _attend_chunked(q, k, v, True, None, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-6)


# ---------------------------------------------------------------------------
# chunked linear attention (mLSTM / Mamba2 SSD core)
# ---------------------------------------------------------------------------
def _sequential_linear_attention(q, k, v, log_decay):
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    S = jnp.zeros((b, h, dk, dv))
    n = jnp.zeros((b, h, dk))
    ys, ns = [], []
    for t in range(s):
        y, S, n = linear_attention_step(q[:, t], k[:, t], v[:, t],
                                        log_decay[:, t], S, n)
        ys.append(y)
        ns.append(n)
    return jnp.stack(ys, 1), S, jnp.stack(ns, 1)


@pytest.mark.parametrize("s,chunk", [(16, 4), (17, 4), (32, 32), (10, 64)])
def test_chunked_linear_attention_matches_sequential(s, chunk):
    rng = np.random.default_rng(s * 31 + chunk)
    b, h, dk, dv = 2, 3, 5, 7
    q = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dv)), jnp.float32)
    ld = jnp.asarray(-rng.random((b, s, h)), jnp.float32)   # log decay <= 0
    y, S, n = chunked_linear_attention(q, k, v, ld, None, chunk)
    y_ref, S_ref, n_ref = _sequential_linear_attention(q, k, v, ld)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref),
                               rtol=1e-4, atol=1e-4)


def test_chunked_linear_attention_state_handoff():
    """Processing [first half] then [second half with carried state] must
    equal processing the whole sequence."""
    rng = np.random.default_rng(5)
    b, s, h, dk, dv, chunk = 1, 24, 2, 4, 4, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dv)), jnp.float32)
    ld = jnp.asarray(-rng.random((b, s, h)), jnp.float32)
    y_full, S_full, _ = chunked_linear_attention(q, k, v, ld, None, chunk)
    y1, S1, n1 = chunked_linear_attention(q[:, :12], k[:, :12], v[:, :12],
                                          ld[:, :12], None, chunk)
    y2, S2, _ = chunked_linear_attention(q[:, 12:], k[:, 12:], v[:, 12:],
                                         ld[:, 12:], S1, chunk,
                                         norm_state=n1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S2), np.asarray(S_full),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), s=st.integers(2, 40),
       chunk=st.integers(1, 16))
def test_property_chunked_linear_attention(seed, s, chunk):
    rng = np.random.default_rng(seed)
    b, h, dk, dv = 1, 2, 3, 3
    q = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dv)), jnp.float32)
    ld = jnp.asarray(-rng.random((b, s, h)) * 2, jnp.float32)
    y, _, _ = chunked_linear_attention(q, k, v, ld, None, chunk)
    y_ref, _, _ = _sequential_linear_attention(q, k, v, ld)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------
def test_moe_dispatch_matches_dense_reference():
    import dataclasses
    from repro.configs import get_config
    from repro.models.moe import init_moe, moe_forward
    cfg = dataclasses.replace(get_config("qwen2_moe_a2p7b").reduced(),
                              capacity_factor=100.0)   # no drops
    p = init_moe(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 9, cfg.d_model)), jnp.float32)
    out, _ = moe_forward(p, cfg, x)

    # dense reference: evaluate every expert on every token
    xf = x.reshape(-1, cfg.d_model)
    probs = jax.nn.softmax(xf @ p["router"], -1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xf)
    for e in range(cfg.num_experts):
        h = jax.nn.silu(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        y = h @ p["w_out"][e]
        w = ((idx == e) * gate).sum(-1)
        ref += y * w[:, None]
    from repro.models.layers import ffn_forward
    for sp in p.get("shared", []):
        ref += ffn_forward(sp, cfg, xf)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens():
    import dataclasses
    from repro.configs import get_config
    from repro.models.moe import init_moe, moe_forward
    cfg = dataclasses.replace(get_config("qwen2_moe_a2p7b").reduced(),
                              capacity_factor=0.05, num_shared_experts=0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 32, cfg.d_model)),
                    jnp.float32)
    out, _ = moe_forward(p, cfg, x)
    # with tiny capacity most tokens are dropped -> many zero rows
    norms = jnp.linalg.norm(out.reshape(-1, cfg.d_model), axis=-1)
    assert float((norms == 0).mean()) > 0.3


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def test_rope_preserves_norm_and_relative_dot():
    from repro.models.config import ModelConfig
    from repro.models.layers import apply_rope
    cfg = ModelConfig(name="t", arch_type="dense", num_layers=1, d_model=64,
                      num_heads=1, num_kv_heads=1, d_ff=1, vocab_size=10)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 8, 1, 64)), jnp.float32)
    pos = jnp.arange(8)[None, :]
    r = apply_rope(x, pos, cfg)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(r), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 64)), jnp.float32)
    dots = []
    for p0 in (0, 5):
        qr = apply_rope(q, jnp.asarray([[p0]]), cfg)
        kr = apply_rope(k, jnp.asarray([[p0 + 3]]), cfg)
        dots.append(float(jnp.sum(qr * kr)))
    assert abs(dots[0] - dots[1]) < 1e-4


def test_partial_rope_rotates_half():
    import dataclasses
    from repro.models.config import ModelConfig
    from repro.models.layers import apply_rope
    cfg = ModelConfig(name="t", arch_type="dense", num_layers=1, d_model=64,
                      num_heads=1, num_kv_heads=1, d_ff=1, vocab_size=10,
                      rope_fraction=0.5)
    x = jnp.ones((1, 4, 1, 64), jnp.float32)
    r = apply_rope(x, jnp.arange(4)[None], cfg)
    # unrotated second half unchanged
    np.testing.assert_array_equal(np.asarray(r[..., 32:]),
                                  np.ones((1, 4, 1, 32)))
    assert not np.allclose(np.asarray(r[:, 1:, :, :32]), 1.0)
