"""Serving subsystem tests (DESIGN.md §13): bundle export/load + staleness
hard errors, LRU cache semantics, continuous-batching flush triggers, the
zero-recompile steady state, inductive-fallback parity with the offline
aggregation, and the degraded zero-neighbor path."""
import json
import os

import numpy as np
import pytest

from repro.pipeline import (Pipeline, PipelineConfig, graph_fingerprint,
                            make_karate_dataset)
from repro.serving import (ContinuousBatcher, EmbeddingStore, LruNodeCache,
                           StaleServingArtifact, bucket_of, bucket_sizes,
                           make_zipf_workload, route_neighbors, run_replay)


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    """One karate pipeline run with the serving export hook on."""
    tmp = tmp_path_factory.mktemp("serving")
    ds = make_karate_dataset()
    cfg = PipelineConfig(dataset="karate", method="leiden_fusion", k=4,
                         mode="local", epochs=3, classifier_epochs=10,
                         hidden_dim=16, embed_dim=16, num_layers=2,
                         dropout=0.0, cache_dir=str(tmp / "cache"),
                         collect_hlo=False, serving_dir=str(tmp / "srv"))
    report = Pipeline(cfg).run(ds)
    return ds, report


@pytest.fixture(scope="module")
def store(served):
    ds, report = served
    return EmbeddingStore.load(report.serving_path,
                               expect_fingerprint=report.partition_fingerprint,
                               expect_graph=graph_fingerprint(ds.graph))


# ---------------------------------------------------------------------------
# export / load / staleness
# ---------------------------------------------------------------------------
def test_pipeline_exports_serving_bundle(served):
    ds, report = served
    assert report.serving_path and os.path.exists(report.serving_path)
    assert report.partition_fingerprint in report.serving_path
    assert "serving" in report.summary()
    with np.load(report.serving_path, allow_pickle=False) as z:
        meta = json.loads(str(z["meta_json"]))
        assert z["embeddings"].shape == (ds.graph.n, 16)
        assert z["predictions"].shape == (ds.graph.n,)
        assert z["head_w"].shape == (4, 16, ds.num_classes)
    assert meta["kind"] == "serving"
    assert meta["partition_fingerprint"] == report.partition_fingerprint
    assert meta["graph"] == graph_fingerprint(ds.graph)


def test_store_shards_partition_the_table(served, store):
    ds, report = served
    assert store.k == 4 and store.n == ds.graph.n
    assert sum(s.num_nodes for s in store.shards) == store.n
    # shard-routed lookup equals the flat table gather
    with np.load(report.serving_path, allow_pickle=False) as z:
        flat = z["embeddings"]
    ids = np.arange(store.n)
    np.testing.assert_array_equal(store.lookup(ids), flat)
    # each shard holds exactly its partition's rows
    for s in store.shards:
        assert (store.partition_of[s.node_ids] == s.pid).all()


def test_stale_bundle_is_a_hard_error(served):
    _, report = served
    with pytest.raises(StaleServingArtifact, match="fingerprint"):
        EmbeddingStore.load(report.serving_path,
                            expect_fingerprint="deadbeef00000000")
    with pytest.raises(StaleServingArtifact, match="graph"):
        EmbeddingStore.load(report.serving_path, expect_graph="bogus")
    srv_dir = os.path.dirname(report.serving_path)
    with pytest.raises(StaleServingArtifact, match="no serving bundle"):
        EmbeddingStore.load(srv_dir, expect_fingerprint="deadbeef00000000")
    # directory resolution picks the matching bundle
    st = EmbeddingStore.load(srv_dir,
                             expect_fingerprint=report.partition_fingerprint)
    assert st.fingerprint == report.partition_fingerprint


def test_serving_dir_requires_classifier(tmp_path):
    cfg = PipelineConfig(dataset="karate", k=4, classifier_epochs=0,
                         serving_dir=str(tmp_path / "srv"))
    with pytest.raises(ValueError, match="classifier"):
        Pipeline(cfg).run(make_karate_dataset())


# ---------------------------------------------------------------------------
# LRU cache
# ---------------------------------------------------------------------------
def test_lru_cache_counters_and_eviction():
    c = LruNodeCache(capacity=2)
    r = lambda i: np.full(3, i, np.float32)
    assert c.get(1) is None and c.misses == 1
    c.put(1, r(1))
    c.put(2, r(2))
    np.testing.assert_array_equal(c.get(1), r(1))   # 1 is now MRU
    c.put(3, r(3))                                  # evicts 2 (LRU)
    assert 2 not in c and 1 in c and 3 in c
    assert c.evictions == 1
    assert c.get(2) is None
    assert c.hits == 1 and c.misses == 2
    assert c.hit_rate == pytest.approx(1 / 3)
    assert c.stats()["size"] == 2
    with pytest.raises(ValueError, match="capacity"):
        LruNodeCache(0)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------
def test_bucket_shapes_are_pow2():
    assert bucket_sizes(64) == (1, 2, 4, 8, 16, 32, 64)
    assert bucket_sizes(48) == (1, 2, 4, 8, 16, 32, 48)
    assert bucket_of(1, 64) == 1
    assert bucket_of(3, 64) == 4
    assert bucket_of(64, 64) == 64


def test_flush_on_max_batch(store):
    b = ContinuousBatcher(store, max_batch=4, max_wait_ms=1e9)
    for i in range(3):
        b.submit(i)
    assert b.pump() == [] and b.pending() == 3   # under batch, under wait
    b.submit(3)
    out = b.pump()                                # 4th query trips the flush
    assert len(out) == 4 and b.pending() == 0
    assert [a.qid for a in out] == [0, 1, 2, 3]


def test_flush_on_max_wait_with_injected_clock(store):
    t = [0.0]
    b = ContinuousBatcher(store, max_batch=64, max_wait_ms=5.0,
                          now=lambda: t[0])
    b.submit(0)
    assert not b.due()
    t[0] = 0.004                                  # 4ms < max_wait
    assert b.pump() == []
    t[0] = 0.006                                  # oldest waited 6ms >= 5ms
    out = b.pump()
    assert len(out) == 1
    assert out[0].latency_ms == pytest.approx(6.0)


def test_replay_exact_match_and_zero_steady_recompiles(store):
    b = ContinuousBatcher(store, cache=LruNodeCache(64), max_batch=16,
                          max_wait_ms=0.5)
    wl = make_zipf_workload(store.n, num_queries=300, unseen_frac=0.05,
                            seed=1)
    row = run_replay(b, wl, verify=True)
    assert row["label_mismatches"] == 0
    assert row["steady_state_recompiles"] == 0
    assert row["warm_compiles"] > 0               # warmup really compiled
    assert row["cache_hit_rate"] > 0
    assert row["served_by_source"].get("degraded", 0) >= 1
    assert row["served_by_source"].get("inductive", 0) >= 1
    assert sum(row["per_shard_served"].values()) == 300


def test_known_answers_match_offline_key(store):
    b = ContinuousBatcher(store, max_batch=8, max_wait_ms=0.1)
    qids = [b.submit(n) for n in range(store.n)]
    answers = {a.qid: a for a in b.drain()}
    for qid, n in zip(qids, range(store.n)):
        a = answers[qid]
        assert a.label == int(store.predictions[n])
        assert a.shard == int(store.partition_of[n])
        assert a.source in ("cache", "store")


# ---------------------------------------------------------------------------
# inductive fallback
# ---------------------------------------------------------------------------
def test_route_neighbors_majority_and_filtering(store):
    p = np.array([0, 0, 1, 1, 1, 2], np.int32)
    pid, nb = route_neighbors(p, [0, 2, 3, 4])
    assert pid == 1 and list(nb) == [0, 2, 3, 4]
    pid, _ = route_neighbors(p, [0, 1, 2, 3])      # 2-2 tie -> smallest pid
    assert pid == 0
    pid, nb = route_neighbors(p, [99, -3])          # out of range: discarded
    assert pid == -1 and nb.size == 0
    pid, nb = route_neighbors(p, None)
    assert pid == -1 and nb.size == 0


@pytest.mark.parametrize("use_kernel", [False, True])
def test_inductive_matches_offline_aggregation(store, use_kernel):
    """A served unseen-node prediction equals the offline reference:
    aggregate_mean over its known neighbors + the owning shard's head."""
    import jax.numpy as jnp
    from repro.gnn.layers import aggregate_mean

    nbs = np.array([0, 1, 2, 5], np.int64)
    pid, known = route_neighbors(store.partition_of, nbs)
    d, e = known.size, store.embed_dim
    # offline reference: the same star-graph aggregate the training path uses
    h = jnp.concatenate([jnp.zeros((1, e), jnp.float32),
                         jnp.asarray(store.lookup(known))])
    agg = aggregate_mean(
        h, jnp.arange(1, d + 1, dtype=jnp.int32),
        jnp.zeros(d, jnp.int32), jnp.ones(d, jnp.float32),
        jnp.concatenate([jnp.array([float(d)]), jnp.ones(d)]),
        use_kernel=use_kernel)[0]
    ref_logits = np.asarray(agg @ store.head_w[pid] + store.head_b[pid])

    b = ContinuousBatcher(store, max_batch=8, max_wait_ms=0.1,
                          use_kernel=use_kernel)
    qid = b.submit(store.n + 7, neighbors=nbs)
    (a,) = b.drain()
    assert a.qid == qid and a.source == "inductive" and a.shard == pid
    np.testing.assert_allclose(a.logits, ref_logits, atol=1e-5)
    assert a.label == int(ref_logits.argmax())


def test_zero_neighbor_query_degrades_not_crashes(store):
    b = ContinuousBatcher(store, max_batch=8, max_wait_ms=0.1)
    b.submit(store.n + 1, neighbors=[])                  # nothing known
    b.submit(store.n + 2, neighbors=[10_000, -1])        # all filtered out
    b.submit(store.n + 3)                                # no list at all
    answers = b.drain()
    assert len(answers) == 3
    for a in answers:
        assert a.source == "degraded"
        assert a.shard == 0                              # computed on shard 0
        assert 0 <= a.label < store.num_classes
        assert np.all(np.asarray(a.embedding) == 0)      # zero aggregate


def test_truncates_neighbor_lists_beyond_max(store):
    b = ContinuousBatcher(store, max_batch=4, max_wait_ms=0.1,
                          max_neighbors=4)
    b.submit(store.n, neighbors=np.arange(20))           # 20 > max_neighbors
    (a,) = b.drain()
    assert a.source == "inductive"


# ---------------------------------------------------------------------------
# workload generator
# ---------------------------------------------------------------------------
def test_zipf_workload_shape_and_unseen(store):
    wl = make_zipf_workload(100, num_queries=500, unseen_frac=0.1, seed=3)
    assert len(wl) == 500
    unseen = [(nid, nb) for nid, nb in wl if nid >= 100]
    assert len(unseen) == 50
    assert sorted(nid for nid, _ in unseen) == list(range(100, 150))
    # the first unseen slot always replays the degraded path
    first = min(unseen, key=lambda x: x[0])
    assert first[1].size == 0
    known = [nid for nid, nb in wl if nid < 100]
    assert all(nb is None for nid, nb in wl if nid < 100)
    # Zipf concentration: the hot set dominates
    _, counts = np.unique(known, return_counts=True)
    assert counts.max() > len(known) * 0.05
