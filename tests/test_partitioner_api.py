"""Partitioner API v2: spec grammar, open registry, capabilities,
fingerprints, and the v1 deprecation shims (DESIGN.md §9)."""
import dataclasses
import warnings

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Capabilities, FusionConfig, LeidenFusionConfig,
                        LpaConfig, MetisConfig, PARTITIONERS, Partitioner,
                        PartitionerSpec, PartitionResult, evaluate_partition,
                        get_entry, get_partitioner, karate_club,
                        make_arxiv_like, partition_from_spec,
                        register_partitioner, registered_partitioners,
                        unregister_partitioner)

BUILTINS = ("leiden_fusion", "lpa", "metis", "random", "single")


# ---------------------------------------------------------------------------
# grammar: parse
# ---------------------------------------------------------------------------
def test_parse_bare_method_gets_default_config():
    s = PartitionerSpec.parse("metis")
    assert s.method == "metis"
    assert s.config == MetisConfig()
    assert s.fusion is None


def test_parse_configured():
    s = PartitionerSpec.parse("lpa(max_iter=30,balance_cap=1.5)")
    assert s.config == LpaConfig(max_iter=30, balance_cap=1.5)


def test_parse_normalizes_case_hyphens_whitespace():
    s = PartitionerSpec.parse("  Leiden-Fusion ( resolution = 0.5 ) ")
    assert s.method == "leiden_fusion"
    assert s.config == LeidenFusionConfig(resolution=0.5)
    assert PartitionerSpec.parse("LPA + F").fusion == FusionConfig()


def test_parse_fusion_combinator_forms():
    bare = PartitionerSpec.parse("metis+f")
    assert bare.fusion == FusionConfig()
    cfgd = PartitionerSpec.parse("lpa(max_iter=20)+f(alpha=0.1,base_k=32)")
    assert cfgd.config == LpaConfig(max_iter=20)
    assert cfgd.fusion == FusionConfig(alpha=0.1, base_k=32)


def test_parse_int_coerced_to_float_field():
    s = PartitionerSpec.parse("leiden_fusion(resolution=2)")
    assert s.config.resolution == 2.0
    assert isinstance(s.config.resolution, float)


def test_legacy_underscore_f_aliases():
    assert PartitionerSpec.parse("metis_f").canonical() == "metis+f"
    assert PartitionerSpec.parse("lpa_f") == PartitionerSpec.parse("lpa+f")


def test_parse_accepts_spec_instance():
    s = PartitionerSpec.parse("metis+f")
    assert PartitionerSpec.parse(s) is s


# ---------------------------------------------------------------------------
# grammar: canonical formatting
# ---------------------------------------------------------------------------
def test_canonical_omits_default_fields():
    assert PartitionerSpec.parse("lpa(max_iter=50)").canonical() == "lpa"
    assert PartitionerSpec.parse(
        "lpa(balance_cap=1.5,max_iter=50)").canonical() == \
        "lpa(balance_cap=1.5)"
    assert PartitionerSpec.parse("metis+f(alpha=0.05)").canonical() == \
        "metis+f"


def test_canonical_field_order_is_declaration_order():
    s = PartitionerSpec.parse("lpa(balance_cap=2.0,max_iter=9)")
    assert s.canonical() == "lpa(max_iter=9,balance_cap=2.0)"


def test_str_is_canonical():
    assert str(PartitionerSpec.parse("metis_f")) == "metis+f"


# ---------------------------------------------------------------------------
# grammar: errors
# ---------------------------------------------------------------------------
def test_unknown_method_lists_available():
    with pytest.raises(ValueError, match="unknown partitioner 'nope'"):
        PartitionerSpec.parse("nope")
    with pytest.raises(ValueError, match="available"):
        partition_from_spec(karate_club(), "nope", 2)


def test_unknown_field_lists_expected():
    with pytest.raises(ValueError, match="unknown field 'gamma'.*expected.*"
                                         "max_iter, balance_cap"):
        PartitionerSpec.parse("lpa(gamma=2)")
    with pytest.raises(ValueError, match=r"unknown field 'beta'.*lpa\+f"):
        PartitionerSpec.parse("lpa+f(beta=0.5)")


def test_syntax_errors():
    for bad in ("", "lpa(", "lpa)", "lpa(max_iter)", "lpa(max_iter=1;2)",
                "lpa(max_iter=1)(x=2)", "lpa+g", "(x=1)"):
        with pytest.raises(ValueError):
            PartitionerSpec.parse(bad)


def test_duplicate_field_rejected():
    with pytest.raises(ValueError, match="duplicate field"):
        PartitionerSpec.parse("lpa(max_iter=1,max_iter=2)")


def test_type_mismatch_rejected():
    with pytest.raises(TypeError, match="max_iter"):
        PartitionerSpec.parse("lpa(max_iter=1.5)")
    with pytest.raises(TypeError, match="balance_cap"):
        PartitionerSpec.parse("lpa(balance_cap=big)")


def test_config_validation_runs_on_parse():
    with pytest.raises(ValueError, match="balance_cap must be >= 1.0"):
        PartitionerSpec.parse("lpa(balance_cap=0.5)")
    with pytest.raises(ValueError, match="resolution must be > 0"):
        PartitionerSpec.parse("leiden_fusion(resolution=0)")
    with pytest.raises(ValueError, match="alpha must be >= 0"):
        PartitionerSpec.parse("metis+f(alpha=-0.1)")


def test_legacy_alias_with_args_is_an_error():
    with pytest.raises(ValueError, match=r"metis\+f"):
        PartitionerSpec.parse("metis_f(alpha=0.1)")


# ---------------------------------------------------------------------------
# grammar: property-based round trip
# ---------------------------------------------------------------------------
@st.composite
def random_specs(draw):
    """A random well-formed spec string over the built-in registry."""
    method = BUILTINS[draw(st.integers(0, len(BUILTINS) - 1))]
    parts = [method]
    fields = []
    if method == "lpa":
        if draw(st.integers(0, 1)):
            fields.append(f"max_iter={draw(st.integers(1, 99))}")
        if draw(st.integers(0, 1)):
            fields.append(f"balance_cap={1.0 + draw(st.integers(0, 300)) / 100}")
    elif method == "metis":
        if draw(st.integers(0, 1)):
            fields.append(f"coarsen_to={draw(st.integers(1, 2000))}")
    elif method == "leiden_fusion":
        if draw(st.integers(0, 1)):
            fields.append(f"alpha={draw(st.integers(0, 100)) / 100}")
        if draw(st.integers(0, 1)):
            fields.append(f"beta={(draw(st.integers(0, 99)) + 1) / 100}")
        if draw(st.integers(0, 1)):
            fields.append(f"resolution={(draw(st.integers(0, 400)) + 1) / 100}")
    if fields:
        pad = " " * draw(st.integers(0, 2))
        parts.append(f"({pad}{f',{pad}'.join(fields)}{pad})")
    if draw(st.integers(0, 1)):                  # append the +f combinator
        parts.append("+f")
        ffields = []
        if draw(st.integers(0, 1)):
            ffields.append(f"alpha={draw(st.integers(0, 100)) / 100}")
        if draw(st.integers(0, 1)):
            ffields.append(f"base_k={draw(st.integers(1, 64))}")
        if ffields:
            parts.append(f"({','.join(ffields)})")
    return "".join(parts)


@settings(max_examples=60, deadline=None)
@given(text=random_specs())
def test_property_spec_round_trip(text):
    """format(parse(s)) is canonical: re-parsing it gives an equal spec,
    an equal fingerprint, and an idempotent canonical form."""
    spec = PartitionerSpec.parse(text)
    canon = spec.canonical()
    again = PartitionerSpec.parse(canon)
    assert again == spec
    assert again.canonical() == canon
    assert again.fingerprint() == spec.fingerprint()


# ---------------------------------------------------------------------------
# fingerprints
# ---------------------------------------------------------------------------
def test_fingerprint_covers_defaults_consistently():
    assert PartitionerSpec.parse("metis(coarsen_to=400)").fingerprint() == \
        PartitionerSpec.parse("metis").fingerprint()


def test_fingerprint_separates_configs():
    fps = {PartitionerSpec.parse(s).fingerprint() for s in (
        "lpa", "lpa(balance_cap=1.1)", "lpa(balance_cap=2.0)",
        "lpa(max_iter=10)", "lpa+f", "lpa+f(alpha=0.1)", "metis", "metis+f",
        "leiden_fusion", "leiden_fusion(resolution=0.5)")}
    assert len(fps) == 9          # lpa(balance_cap=1.1) == lpa (the default)


def test_fingerprint_is_stable_value():
    fp = PartitionerSpec.parse("lpa+f(alpha=0.1)").fingerprint()
    assert fp == PartitionerSpec.parse("lpa + f ( alpha = 0.1 )").fingerprint()
    assert len(fp) == 16 and int(fp, 16) >= 0


# ---------------------------------------------------------------------------
# registry + protocol
# ---------------------------------------------------------------------------
def test_builtins_registered():
    assert tuple(registered_partitioners()) == BUILTINS


def test_entries_satisfy_protocol():
    for entry in registered_partitioners().values():
        assert isinstance(entry, Partitioner)
        assert dataclasses.is_dataclass(entry.config_type)


def test_entry_partition_returns_result():
    g = karate_club()
    res = get_entry("metis").partition(g, 2, seed=0)
    assert isinstance(res, PartitionResult)
    assert res.labels.shape == (g.n,)
    assert res.spec == "metis"
    with pytest.raises(TypeError, match="expects a MetisConfig"):
        get_entry("metis").partition(g, 2, config=LpaConfig())


def test_open_registry_register_and_use():
    @register_partitioner("stripes", config=LpaConfig,
                          capabilities=Capabilities(balanced=True),
                          doc="contiguous equal stripes (test partitioner)")
    def stripes(g, k, seed, cfg):
        return (np.arange(g.n) * k // g.n).astype(np.int64)

    try:
        g = karate_club()
        res = partition_from_spec(g, "stripes(max_iter=3)", 2)
        assert res.num_parts == 2
        # the +f combinator composes over the new method for free
        rep = evaluate_partition(g, partition_from_spec(g, "stripes+f", 2).labels)
        assert rep.total_isolated == 0
        # re-registration guarded
        with pytest.raises(ValueError, match="already registered"):
            register_partitioner("stripes")(stripes)
        register_partitioner("stripes", overwrite=True)(stripes)
    finally:
        unregister_partitioner("stripes")
    with pytest.raises(ValueError, match="unknown partitioner"):
        PartitionerSpec.parse("stripes")


# ---------------------------------------------------------------------------
# capabilities + the paper's guarantees through the v2 API
# ---------------------------------------------------------------------------
def test_string_config_fields_round_trip():
    """Open-registry methods may declare str fields; quoted values survive
    commas/equals and canonical formatting re-quotes them."""
    @dataclasses.dataclass(frozen=True)
    class TagConfig:
        tag: str = "x"

    @register_partitioner("tagged", config=TagConfig)
    def tagged(g, k, seed, cfg):
        return np.zeros(g.n, dtype=np.int64)

    try:
        s = PartitionerSpec.parse("tagged(tag='a,b=c')")
        assert s.config.tag == "a,b=c"
        assert s.canonical() == "tagged(tag='a,b=c')"
        assert PartitionerSpec.parse(s.canonical()) == s
        # barewords stay unquoted; keyword-like strings get quoted
        assert PartitionerSpec.parse("tagged(tag=word)").canonical() == \
            "tagged(tag=word)"
        spec = PartitionerSpec(method="tagged", config=TagConfig(tag="none"))
        assert spec.canonical() == "tagged(tag='none')"
        assert PartitionerSpec.parse(spec.canonical()) == spec
        # parens inside quoted values survive the grammar too
        parens = PartitionerSpec(method="tagged", config=TagConfig(tag="(x)"))
        assert PartitionerSpec.parse(parens.canonical()) == parens
    finally:
        unregister_partitioner("tagged")


def test_coercion_handles_pep604_unions():
    """`int | None` (PEP 604) fields validate like Optional[int]."""
    @dataclasses.dataclass(frozen=True)
    class NewConfig:
        cap: int | None = None

    @register_partitioner("newstyle", config=NewConfig)
    def newstyle(g, k, seed, cfg):
        return np.zeros(g.n, dtype=np.int64)

    try:
        assert PartitionerSpec.parse("newstyle(cap=none)").config.cap is None
        parsed = PartitionerSpec.parse("newstyle(cap=2.0)").config.cap
        assert parsed == 2 and isinstance(parsed, int)
        with pytest.raises(TypeError, match="cap"):
            PartitionerSpec.parse("newstyle(cap=1.5)")
    finally:
        unregister_partitioner("newstyle")


def test_capability_flags():
    assert PartitionerSpec.parse("leiden_fusion").capabilities \
        .connectivity_guaranteed
    assert not PartitionerSpec.parse("metis").capabilities \
        .connectivity_guaranteed
    # any +f variant is connectivity-guaranteed, whatever the base; balance
    # stays the base's claim (fuse's size cap is only best-effort)
    for base in ("metis", "lpa", "random"):
        caps = PartitionerSpec.parse(f"{base}+f").capabilities
        assert caps.connectivity_guaranteed
        base_caps = PartitionerSpec.parse(base).capabilities
        assert caps.balanced == base_caps.balanced
    assert not PartitionerSpec.parse("random+f").capabilities.balanced


@pytest.mark.parametrize("karate_spec,arxiv_spec", [
    # loose alpha on the 34-node karate club, as in the seed tests; metis
    # additionally over-partitions (base_k) there — at k=4 on 34 nodes it
    # yields 4 already-connected parts and fusion has nothing to fuse
    ("leiden_fusion(alpha=0.5)", "leiden_fusion"),
    ("metis+f(alpha=0.5,base_k=8)", "metis+f"),
    ("lpa+f(alpha=0.5)", "lpa+f(alpha=0.2)"),
])
def test_connectivity_guaranteed_specs_deliver(karate_spec, arxiv_spec):
    """Capability flags are honest: connectivity-guaranteed specs produce
    zero isolated nodes and single-component partitions."""
    cases = ((karate_club(), karate_spec),
             (make_arxiv_like(n=1000, seed=4).graph, arxiv_spec))
    for g, spec in cases:
        assert PartitionerSpec.parse(spec).capabilities \
            .connectivity_guaranteed
        res = partition_from_spec(g, spec, 4, seed=0)
        rep = evaluate_partition(g, res.labels)
        assert res.num_parts == 4
        assert rep.total_isolated == 0
        assert rep.max_components == 1


def test_partition_result_provenance_and_determinism():
    g = karate_club()
    a = partition_from_spec(g, "lpa+f(alpha=0.1)", 4, seed=3)
    b = partition_from_spec(g, "lpa + f (alpha=0.1)", 4, seed=3)
    np.testing.assert_array_equal(a.labels, b.labels)
    assert a.spec == b.spec == "lpa+f(alpha=0.1)"
    assert a.fingerprint == b.fingerprint
    assert a.seconds >= 0 and a.k == 4 and a.seed == 3
    assert a.provenance["method"] == "lpa"
    assert "base_seconds" in a.provenance
    assert "fusion_seconds" in a.provenance
    assert a.provenance["base_communities"] >= 4


def test_resolution_reaches_leiden():
    g = make_arxiv_like(n=800, seed=1).graph
    hi = partition_from_spec(g, "leiden_fusion(resolution=4.0)", 4, seed=0)
    lo = partition_from_spec(g, "leiden_fusion", 4, seed=0)
    assert hi.fingerprint != lo.fingerprint
    # the config actually reaches leiden: gamma=4 changes the partition
    assert not np.array_equal(hi.labels, lo.labels)
    for res in (hi, lo):
        rep = evaluate_partition(g, res.labels)
        assert rep.total_isolated == 0 and rep.max_components == 1


def test_base_k_gives_base_method_a_different_target():
    g = make_arxiv_like(n=800, seed=1).graph
    res = partition_from_spec(g, "metis+f(base_k=16)", 4, seed=0)
    assert res.num_parts == 4
    assert res.provenance["base_communities"] >= 16


# ---------------------------------------------------------------------------
# v1 deprecation shims (pinned behavior)
# ---------------------------------------------------------------------------
def test_get_partitioner_shim_warns_and_matches_v2():
    g = karate_club()
    with pytest.warns(DeprecationWarning, match="get_partitioner"):
        fn = get_partitioner("lpa")
    np.testing.assert_array_equal(
        fn(g, 2, seed=0), partition_from_spec(g, "lpa", 2, seed=0).labels)
    # kwargs overrides still reach the typed config
    np.testing.assert_array_equal(
        fn(g, 2, seed=0, max_iter=3),
        partition_from_spec(g, "lpa(max_iter=3)", 2, seed=0).labels)


def test_partitioners_dict_shim():
    g = karate_club()
    assert set(PARTITIONERS) == {"single", "random", "lpa", "metis",
                                 "leiden_fusion", "metis_f", "lpa_f"}
    assert len(PARTITIONERS) == 7
    with pytest.warns(DeprecationWarning, match="PARTITIONERS"):
        fn = PARTITIONERS["metis_f"]
    labels = fn(g, 2, seed=0)
    np.testing.assert_array_equal(
        labels, partition_from_spec(g, "metis+f", 2, seed=0).labels)


def test_registry_selfcheck_tool():
    """tools/registry_selfcheck.py --emit: every entry runs on karate club
    with its default config and prints a stable fingerprint line. (CI runs
    the tool's full two-process comparison as its own step, so the test
    only exercises the single-process validation pass.)"""
    import os
    import re
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, os.path.join(repo, "tools",
                                      "registry_selfcheck.py"), "--emit"],
        capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    lines = out.stdout.strip().splitlines()
    assert len(lines) == 2 * len(registered_partitioners())
    assert all(re.fullmatch(r"\S+ [0-9a-f]{16}", ln) for ln in lines), lines


def test_shims_raise_keyerror_on_unknown():
    with pytest.raises(KeyError, match="unknown partitioner"):
        get_partitioner("nope")
    with pytest.raises(KeyError, match="available"):
        PARTITIONERS["nope"]
    # no DeprecationWarning fires for the failed lookup
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        with pytest.raises(KeyError):
            get_partitioner("nope")
