"""Fused GNN-layer kernel + autotuner tests (DESIGN.md §14).

Pins: (1) forward/backward parity of every KernelConfig strategy against
the jnp composition (`fused_gcn_reference`), including non-multiple-of-tile
shapes, duplicate destinations, and zero-degree nodes; (2) a finite-
difference probe of the fused custom VJP; (3) the layer entry points
(`gcn_layer` / `sage_layer` / `gnn_forward`) matching the jnp path under a
forced pallas config — the surface sync/stale/local training all consume;
(4) autotune cache determinism across processes; (5) the structured shape-
contract error; (6) the VMEM-filtered candidate space.
"""
import json
import os
import subprocess
import sys

import numpy as np

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import fused_gcn_layer
from repro.kernels.autotune import (VMEM_BUDGET, KernelConfig, ShapeBucket,
                                    autotune, candidate_space,
                                    clear_memory_cache, get_config, override,
                                    shape_bucket, vmem_bytes)
from repro.kernels.csr_aggregate import (ShapeContractError,
                                         csr_aggregate_pallas)
from repro.kernels.fused_layer import fused_gcn_reference

STRATEGIES = ("pallas_fused", "pallas", "xla")


def _star_graph(seed, n, f, e, fo):
    """Random graph with duplicate destinations AND zero-degree nodes
    (dst drawn from the first half of the rows only)."""
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(np.sort(rng.integers(0, max(n // 2, 1), e)), jnp.int32)
    w_edge = jnp.asarray(rng.random(e), jnp.float32)
    deg = jnp.asarray(np.bincount(np.asarray(dst), minlength=n)[:n],
                      jnp.float32)
    w = jnp.asarray(rng.normal(size=(f, fo)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(fo,)) * 0.1, jnp.float32)
    return h, src, dst, w_edge, deg, w, b


def _reference(h, src, dst, w_edge, deg, w, b, activate):
    inv = 1.0 / jnp.maximum(deg, 1.0)
    return fused_gcn_reference(h, src, dst, w_edge, inv, w, b,
                               activate=activate)


# ---------------------------------------------------------------------------
# strategy parity: forward
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,f,e,fo", [
    (8, 16, 32, 16),        # tiny, aligned-ish
    (100, 24, 700, 50),     # unaligned everything
    (600, 40, 1500, 24),    # node-tiled (n > default tile when forced small)
])
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("activate", [True, False])
def test_fused_layer_strategy_forward_parity(n, f, e, fo, strategy, activate):
    h, src, dst, w_edge, deg, w, b = _star_graph(n * 3 + fo, n, f, e, fo)
    cfg = KernelConfig(strategy=strategy)
    out = fused_gcn_layer(h, src, dst, w_edge, deg, w, b,
                          activate=activate, config=cfg)
    ref = _reference(h, src, dst, w_edge, deg, w, b, activate)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_fused_layer_streamed_config_parity():
    """stream > 1 changes the DMA granule, never the result."""
    h, src, dst, w_edge, deg, w, b = _star_graph(7, 100, 24, 700, 16)
    ref = _reference(h, src, dst, w_edge, deg, w, b, True)
    for stream in (1, 2, 4):
        cfg = KernelConfig(strategy="pallas_fused", node_tile=64,
                           edge_block=128, feat_tile=128, stream=stream)
        out = fused_gcn_layer(h, src, dst, w_edge, deg, w, b, config=cfg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# strategy parity: gradients
# ---------------------------------------------------------------------------
def _grads(cfg, h, src, dst, w_edge, deg, w, b):
    def loss(h, w_edge, w, b):
        out = fused_gcn_layer(h, src, dst, w_edge, deg, w, b,
                              activate=True, config=cfg)
        return jnp.sum(out * out)
    return jax.value_and_grad(loss, argnums=(0, 1, 2, 3))(h, w_edge, w, b)


@pytest.mark.parametrize("n,f,e,fo", [
    (8, 16, 32, 16),
    (100, 24, 700, 50),
])
@pytest.mark.parametrize("strategy", ["pallas_fused", "pallas"])
def test_fused_layer_strategy_grad_parity(n, f, e, fo, strategy):
    h, src, dst, w_edge, deg, w, b = _star_graph(n + fo, n, f, e, fo)
    val, grads = _grads(KernelConfig(strategy=strategy),
                        h, src, dst, w_edge, deg, w, b)
    ref_val, ref_grads = _grads(KernelConfig(strategy="xla"),
                                h, src, dst, w_edge, deg, w, b)
    np.testing.assert_allclose(float(val), float(ref_val), rtol=1e-4)
    for name, g, rg in zip(("dh", "dw_edge", "dW", "db"), grads, ref_grads):
        np.testing.assert_allclose(np.asarray(g), np.asarray(rg),
                                   rtol=3e-4, atol=3e-4, err_msg=name)


def test_fused_layer_finite_difference_probe():
    """The custom VJP agrees with a central finite difference (directional
    derivative w.r.t. every differentiable argument)."""
    h, src, dst, w_edge, deg, w, b = _star_graph(11, 8, 8, 16, 8)
    cfg = KernelConfig(strategy="pallas_fused")
    rng = np.random.default_rng(3)

    def loss(h, w_edge, w, b):
        out = fused_gcn_layer(h, src, dst, w_edge, deg, w, b,
                              activate=True, config=cfg)
        return float(jnp.sum(out * out))

    args = [h, w_edge, w, b]
    _, grads = _grads(cfg, h, src, dst, w_edge, deg, w, b)
    eps = 1e-3
    for i, (arg, g) in enumerate(zip(args, grads)):
        d = jnp.asarray(rng.normal(size=arg.shape), jnp.float32)
        plus = list(args)
        minus = list(args)
        plus[i] = arg + eps * d
        minus[i] = arg - eps * d
        fd = (loss(*plus) - loss(*minus)) / (2 * eps)
        analytic = float(jnp.vdot(g, d))
        np.testing.assert_allclose(analytic, fd, rtol=5e-2, atol=5e-2)


def test_fused_layer_zero_degree_rows_are_bias_only():
    """A node with no in-edges aggregates to 0 → out = act(b) exactly, on
    every strategy (the relu grad-at-zero convention depends on this row
    class existing)."""
    h = jnp.ones((16, 8), jnp.float32)
    src = jnp.zeros((8,), jnp.int32)
    dst = jnp.zeros((8,), jnp.int32)            # rows 1.. have degree 0
    w_edge = jnp.ones((8,), jnp.float32)
    deg = jnp.zeros((16,), jnp.float32).at[0].set(8.0)
    w = jnp.eye(8, dtype=jnp.float32)
    b = jnp.asarray(np.linspace(-1, 1, 8), jnp.float32)
    for strategy in STRATEGIES:
        out = fused_gcn_layer(h, src, dst, w_edge, deg, w, b,
                              activate=True,
                              config=KernelConfig(strategy=strategy))
        np.testing.assert_allclose(np.asarray(out[1:]),
                                   np.tile(np.maximum(np.asarray(b), 0.0),
                                           (15, 1)),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# layer entry points under a forced pallas config (the training surface)
# ---------------------------------------------------------------------------
def test_gcn_and_sage_layer_match_jnp_under_forced_pallas():
    from repro.gnn.layers import (gcn_layer, init_gcn_layer, init_sage_layer,
                                  sage_layer)
    h, src, dst, w_edge, deg, _, _ = _star_graph(5, 60, 12, 200, 12)
    key = jax.random.PRNGKey(0)
    for layer, init in ((gcn_layer, init_gcn_layer),
                        (sage_layer, init_sage_layer)):
        params = init(key, 12, 20)
        ref = layer(params, h, src, dst, w_edge, deg, use_kernel=False)
        with override(KernelConfig(strategy="pallas_fused")):
            out = layer(params, h, src, dst, w_edge, deg, use_kernel=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)


def test_gnn_forward_grads_match_jnp_under_forced_pallas():
    """Full multi-layer body (what local/sync/stale steps differentiate):
    values AND grads match the jnp path under a forced fused config."""
    from repro.gnn import GNNConfig, init_gnn
    from repro.gnn.model import gnn_forward
    h, src, dst, w_edge, deg, _, _ = _star_graph(9, 50, 8, 180, 8)
    mk = lambda uk: GNNConfig(kind="gcn", feature_dim=8, hidden_dim=16,
                              embed_dim=16, num_layers=2, dropout=0.0,
                              use_kernel=uk)
    params = init_gnn(jax.random.PRNGKey(1), mk(False))

    def loss(params, cfg):
        emb = gnn_forward(params, cfg, h, src, dst, w_edge, deg)
        return jnp.sum(emb * emb)

    ref_val, ref_g = jax.value_and_grad(loss)(params, mk(False))
    with override(KernelConfig(strategy="pallas_fused")):
        val, g = jax.value_and_grad(loss)(params, mk(True))
    np.testing.assert_allclose(float(val), float(ref_val), rtol=1e-4)
    flat, _ = jax.tree_util.tree_flatten(
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), g, ref_g))
    assert max(flat) < 3e-4, flat


# ---------------------------------------------------------------------------
# autotune: resolution, candidates, cross-process cache determinism
# ---------------------------------------------------------------------------
def test_get_config_fallback_and_override():
    clear_memory_cache()
    cfg = get_config(100, 700, 24, backend="cpu")
    assert cfg.strategy == "xla"
    tpu = get_config(100, 700, 24, backend="tpu")
    assert tpu.uses_pallas
    forced = KernelConfig(strategy="pallas", node_tile=256)
    with override(forced):
        assert get_config(100, 700, 24, backend="cpu") is forced


def test_shape_bucket_is_stable_within_pow2_ranges():
    assert shape_bucket(100, 700, 24) == shape_bucket(128, 1024, 128)
    assert shape_bucket(100, 700, 24).key == "n128_e1024_f128"
    assert shape_bucket(129, 1025, 129).key == "n256_e2048_f256"


def test_candidate_space_respects_vmem_budget():
    bucket = ShapeBucket(n=8192, e=65536, f=128)
    cands = candidate_space(bucket, backend="tpu")
    assert cands, "tile sweep must not be empty for a mid-size bucket"
    for cfg in cands:
        assert cfg.uses_pallas
        assert vmem_bytes(bucket, cfg) <= VMEM_BUDGET
        assert cfg.edge_granule <= bucket.e


def test_candidate_space_past_gather_cliff_falls_back_to_xla():
    # N·FT alone blows the budget past ~28k padded nodes (DESIGN.md §14).
    bucket = ShapeBucket(n=1 << 20, e=1 << 22, f=128)
    cands = candidate_space(bucket, backend="tpu")
    assert [c.strategy for c in cands] == ["xla"]


def test_candidate_space_cpu_default_is_xla_only():
    env = os.environ.pop("REPRO_AUTOTUNE_EXHAUSTIVE", None)
    try:
        cands = candidate_space(ShapeBucket(512, 2048, 128), backend="cpu")
        assert [c.strategy for c in cands] == ["xla"]
    finally:
        if env is not None:
            os.environ["REPRO_AUTOTUNE_EXHAUSTIVE"] = env


_TUNE_SNIPPET = """
import json, sys
from repro.kernels.autotune import autotune, get_config
cfg, measured = autotune(600, 1500, 40)
print(json.dumps({"config": cfg.as_dict(), "measured": bool(measured),
                  "resolved": get_config(600, 1500, 40).as_dict()}))
"""


def test_autotune_cache_is_deterministic_across_processes(tmp_path):
    """Two fresh processes sharing REPRO_AUTOTUNE_CACHE resolve the same
    config; the second is a pure cache hit (no re-measurement)."""
    cache = tmp_path / "autotune_cache.json"
    env = dict(os.environ, REPRO_AUTOTUNE_CACHE=str(cache),
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    outs = []
    for _ in range(2):
        r = subprocess.run([sys.executable, "-c", _TUNE_SNIPPET], env=env,
                           capture_output=True, text=True, check=True)
        outs.append(json.loads(r.stdout.strip().splitlines()[-1]))
    assert outs[0]["config"] == outs[1]["config"]
    assert outs[0]["resolved"] == outs[0]["config"]
    assert not outs[1]["measured"], "second process must hit the disk cache"
    data = json.loads(cache.read_text())
    entries = data["configs"][jax.default_backend()]
    (key,) = entries.keys()
    assert key == shape_bucket(600, 1500, 40).key
    assert entries[key]["source"] == "tuned"


def test_autotune_in_process_cache_hit_returns_no_measurements(tmp_path,
                                                               monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "cache.json"))
    clear_memory_cache()
    try:
        cfg1, _ = autotune(100, 700, 24)
        cfg2, measured = autotune(100, 700, 24)
        assert cfg1 == cfg2
        assert measured == {}
    finally:
        clear_memory_cache()


# ---------------------------------------------------------------------------
# structured shape-contract error (S6)
# ---------------------------------------------------------------------------
def test_shape_contract_error_names_constraint_and_nearest_shape():
    h = jnp.ones((100, 50), jnp.float32)     # F=50 violates feat_tile=128
    src = jnp.zeros((700,), jnp.int32)       # E=700 violates granule
    dst = jnp.zeros((700,), jnp.int32)
    w = jnp.ones((700,), jnp.float32)
    with pytest.raises(ShapeContractError) as ei:
        csr_aggregate_pallas(h, src, dst, w, num_nodes=100)
    err = ei.value
    assert any("F=50" in f for f in err.failures)
    assert any("E=700" in f for f in err.failures)
    assert any("N=100" in f for f in err.failures)   # not a multiple of 8
    assert err.valid == (104, 128, 768)
    assert "repro.kernels.ops.csr_aggregate" in str(err)


def test_shape_contract_error_fused_output_lanes():
    from repro.kernels.fused_layer import fused_gcn_pallas
    h = jnp.ones((8, 128), jnp.float32)
    src = jnp.zeros((256,), jnp.int32)
    dst = jnp.zeros((256,), jnp.int32)
    w_edge = jnp.ones((256,), jnp.float32)
    wmat = jnp.ones((128, 60), jnp.float32)  # FO=60: not a lane multiple
    b = jnp.zeros((60,), jnp.float32)
    with pytest.raises(ShapeContractError, match="FO=60"):
        fused_gcn_pallas(h, src, dst, w_edge, num_nodes=8, wmat=wmat, b=b,
                         config=KernelConfig(strategy="pallas_fused",
                                             stream=1))


# ---------------------------------------------------------------------------
# serving integration (S2): engine config resolution
# ---------------------------------------------------------------------------
def test_inductive_engine_resolves_kernel_config():
    from repro.serving.inductive import InductiveEngine

    class _Store:
        embed_dim = 16
        partition_of = np.zeros(8, np.int64)

    eng = InductiveEngine(_Store(), max_neighbors=4, use_kernel=True)
    cfg = eng.kernel_config(8)
    assert isinstance(cfg, KernelConfig)
    assert cfg == get_config(8 * 5, 8 * 4, 16)
    assert InductiveEngine(_Store(), max_neighbors=4,
                           use_kernel=False).kernel_config(8) is None
