"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (2 layers, d_model<=512, <=4 experts) runs one forward/train
step on CPU; output shapes asserted, no NaNs. Plus decode-path checks and
the prefill->decode == train-forward consistency test."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (init_cache, init_model, make_batch, serve_step,
                          train_loss, model_hidden_train)
from repro.models.lm import grow_cache, prefill_step
from repro.optim import adamw_init, adamw_update

REDUCED = {a: get_config(a).reduced() for a in ARCH_IDS}


def _enc_len(cfg):
    return 16 if cfg.encoder_layers else 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = REDUCED[arch]
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, batch=2, seq=64, seed=1)

    @jax.jit
    def step(p, opt, b):
        loss, g = jax.value_and_grad(lambda p: train_loss(p, cfg, b))(p)
        p, opt = adamw_update(g, opt, p, 1e-3)
        return p, opt, loss

    opt = adamw_init(params)
    p1, opt, loss1 = step(params, opt, batch)
    _, _, loss2 = step(p1, opt, batch)
    assert np.isfinite(float(loss1)) and np.isfinite(float(loss2))
    assert float(loss2) < float(loss1)          # one step of progress
    # hidden states have the right shape and are finite
    h, aux = jax.jit(lambda p, b: model_hidden_train(
        p, cfg, b["tokens"], b.get("patch_embeds"), b.get("frames")))(
        params, batch)
    assert h.shape == (2, 64, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = REDUCED[arch]
    params = init_model(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, 2, 96, enc_len=_enc_len(cfg))
    if cfg.encoder_layers:
        cache["memory"] = jnp.asarray(
            np.random.default_rng(0).normal(0, 0.02, (2, 16, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    tok = jnp.ones((2, 1), jnp.int32)
    lengths = jnp.zeros((2,), jnp.int32)
    step = jax.jit(lambda p, t, c, l: serve_step(p, cfg, t, c, l))
    logits, cache = step(params, tok, cache, lengths)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # a second token with advanced lengths also works
    logits2, _ = step(params, tok, cache, lengths + 1)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_train_forward(arch):
    """Strongest cache-correctness check: running S tokens through prefill
    then decoding token S must equal the train-forward logits at position S.

    Covers KV caches, MLA compressed caches, ring buffers, SSM states and
    the chunked-vs-stepwise linear attention math."""
    import dataclasses
    cfg = REDUCED[arch]
    if cfg.num_experts:
        # decode never drops tokens; make train-side routing drop-free too so
        # the two paths are comparable (drops are expected MoE semantics)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_model(jax.random.PRNGKey(0), cfg)
    s = 33                                       # odd, crosses chunk edges
    batch = make_batch(cfg, batch=2, seq=s + 1, seed=3)

    # reference: full forward, logits at position s-1 predict token s
    h, _ = jax.jit(lambda p, b: model_hidden_train(
        p, cfg, b["tokens"][:, :s], b.get("patch_embeds"),
        b.get("frames")))(params, batch)
    from repro.models.lm import _head_weight, apply_norm
    ref_logits = (h[:, -1] @ _head_weight(params)).astype(jnp.float32)

    # prefill s-1 tokens, then decode token s-1
    pre = {"tokens": batch["tokens"][:, :s - 1]}
    if "patch_embeds" in batch:
        pre["patch_embeds"] = batch["patch_embeds"]
    if "frames" in batch:
        pre["frames"] = batch["frames"]
    _, cache, lengths = jax.jit(
        lambda p, b: prefill_step(p, cfg, b))(params, pre)
    cache = grow_cache(cache, s + 8)
    tok = batch["tokens"][:, s - 1:s]
    logits, _ = jax.jit(lambda p, t, c, l: serve_step(p, cfg, t, c, l))(
        params, tok, cache, lengths)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=2e-2, atol=2e-2)


def test_moe_router_balance_loss_positive():
    cfg = REDUCED["qwen2_moe_a2p7b"]
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, batch=2, seq=64)
    _, aux = jax.jit(lambda p, b: model_hidden_train(p, cfg, b["tokens"]))(
        params, batch)
    assert float(aux) >= 0.99   # E * sum(f*P) >= 1 at uniform routing


def test_sliding_window_cache_is_ring_buffer():
    import dataclasses
    cfg = dataclasses.replace(REDUCED["qwen3_4b"], attention="sliding",
                              window=16)
    params = init_model(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, 1, 1024)
    # ring buffer: cache seq dim == window, not 1024
    k_shape = jax.tree.leaves(cache["layers"])[0].shape
    assert 16 in k_shape
    tok = jnp.ones((1, 1), jnp.int32)
    step = jax.jit(lambda p, t, c, l: serve_step(p, cfg, t, c, l))
    lengths = jnp.asarray([40], jnp.int32)       # beyond the window
    logits, _ = step(params, tok, cache, lengths)
    assert bool(jnp.isfinite(logits).all())


def test_param_count_sane():
    """Full configs should land near their nameplate sizes.  (xlstm is
    excluded: our blocks omit the reference up-projections — documented in
    DESIGN.md — so the implementation is legitimately ~60M.)"""
    approx = {
        "nemotron4_340b": (340e9, 0.15),
        "deepseek_v2_236b": (236e9, 0.20),
        "qwen3_4b": (4e9, 0.35),
        "glm4_9b": (9e9, 0.35),
        "qwen2_moe_a2p7b": (14.3e9, 0.25),   # total (A2.7B = active)
    }
    for arch, (target, tol) in approx.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n, target)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_formula_matches_init(arch):
    """config.param_count() (used for MODEL_FLOPS in the roofline) must track
    the actually-initialized parameter totals."""
    cfg = REDUCED[arch]
    params = init_model(jax.random.PRNGKey(0), cfg)
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    predicted = cfg.param_count()
    if cfg.encoder_layers:        # formula covers the decoder stack only
        enc = sum(int(np.prod(l.shape)) for l in
                  jax.tree.leaves(params["encoder"]))
        actual -= enc
    assert abs(actual - predicted) / actual < 0.15, (arch, actual, predicted)
