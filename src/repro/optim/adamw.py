"""AdamW with decoupled weight decay and global-norm clipping.

State is a pytree mirroring the params, so it shards exactly like the
params under pjit (the dry-run relies on this: optimizer state inherits the
weight PartitionSpecs).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jnp.ndarray          # scalar int32
    mu: PyTree                 # first moment
    nu: PyTree                 # second moment


def adamw_init(params: PyTree) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.zeros_like, zeros))


def _global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads: PyTree, state: OptState, params: PyTree,
                 lr: jnp.ndarray | float, *, b1: float = 0.9,
                 b2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.0,
                 clip_norm: Optional[float] = 1.0
                 ) -> Tuple[PyTree, OptState]:
    """One AdamW step. Returns (new_params, new_state)."""
    step = state.step + 1
    if clip_norm is not None:
        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step=step, mu=mu, nu=nu)


def sgd_update(grads: PyTree, params: PyTree, lr: float) -> PyTree:
    return jax.tree.map(lambda p, g: p - lr * g, params, grads)
