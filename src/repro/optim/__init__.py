"""Optimizers and schedules (pytree-native; no optax dependency)."""
from .adamw import OptState, adamw_init, adamw_update, sgd_update
from .schedules import constant_schedule, cosine_schedule, linear_warmup_cosine

__all__ = ["OptState", "adamw_init", "adamw_update", "sgd_update",
           "constant_schedule", "cosine_schedule", "linear_warmup_cosine"]
