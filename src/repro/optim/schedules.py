"""Learning-rate schedules as step -> lr callables (jit-safe)."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1
                    ) -> Schedule:
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def linear_warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.05) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = lr * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn
