"""Roofline-term extraction from compiled/lowered HLO.

``collective_bytes`` parses the optimized (post-SPMD) per-device HLO text and
sums the RESULT-shape bytes of every communication op. Shapes in the
partitioned module are per-device, so the total is bytes-through-the-links
per device per step (the §Roofline collective term divides by one chip's
link bandwidth).
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one shape like ``f32[16,128]`` (layout suffix ignored)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


# matches:  %name = f32[8,16]{1,0} all-reduce(...)   and tuple results
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?[\s(]")


def normalize_cost_analysis(ca) -> Dict[str, float]:
    """XLA ``Compiled.cost_analysis()`` as one flat dict.

    Newer jax returns a single dict; older versions return one dict per
    device (a list). Callers always want the per-device view."""
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-type result bytes, plus 'total'. Start/done pairs of
    async collectives are counted once (the -start op carries the shape)."""
    out: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    counts: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":       # repeats the -start op's shape
            continue
        out[op] += _shape_bytes(shape_str)
        counts[op] += 1
    out["total"] = sum(out[op] for op in COLLECTIVE_OPS)
    out.update({f"n_{op}": counts[op] for op in COLLECTIVE_OPS})
    return out


def hlo_op_histogram(hlo_text: str, top: int = 15) -> Dict[str, int]:
    """Quick profile of the optimized module: op name -> count."""
    ops: Dict[str, int] = {}
    for m in re.finditer(r"=\s*(?:\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s+"
                         r"([a-z][a-z0-9-]*)", hlo_text):
        op = m.group(1)
        ops[op] = ops.get(op, 0) + 1
    return dict(sorted(ops.items(), key=lambda kv: -kv[1])[:top])


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int, peak_flops: float = 197e12,
                   hbm_bw: float = 819e9, link_bw: float = 50e9
                   ) -> Dict[str, float]:
    """Three roofline times in seconds (per step, per chip).

    ``flops``/``hbm_bytes`` are per-device numbers from cost_analysis of the
    partitioned module; ``coll_bytes`` per-device from collective_bytes."""
    compute = flops / peak_flops
    memory = hbm_bytes / hbm_bw
    collective = coll_bytes / link_bw
    dominant = max((compute, "compute"), (memory, "memory"),
                   (collective, "collective"))
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dominant[1],
            "bound_s": dominant[0]}
