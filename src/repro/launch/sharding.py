"""Sharding rules: params, optimizer state, inputs, caches.

Two weight-sharding modes:

* ``dp_tp`` (baseline) — tensor parallel over ``model``, weights replicated
  across ``data``/``pod`` (classic DP+TP; gradient all-reduce over data).
* ``fsdp_tp`` — additionally shards the non-TP weight dim over the combined
  data axes (ZeRO-3-style; all-gather at use). Required for nemotron-340b /
  deepseek-v2 to fit 16 GB/chip — see EXPERIMENTS.md §Perf.

Every rule is divisibility-guarded: if a dim doesn't divide by the mesh axis
size the axis is dropped for that dim (falls back to replication) — this is
what lets ONE rule set cover head counts from 4 to 128 and vocabs that are
not multiples of 16.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import batch_axes

PyTree = Any


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _guard(mesh: Mesh, spec: P, shape: Tuple[int, ...]) -> P:
    """Drop spec axes whose size doesn't divide the dim."""
    out = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is not None and dim % _axis_size(mesh, axes) == 0 and dim > 0:
            out.append(axes)
        else:
            out.append(None)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
    return "/".join(parts)


# name-pattern -> spec template; "M" = model axis, "F" = fsdp axis
# templates apply to the LAST len(template) dims (stacked scan layers add a
# leading layer dim which is never sharded). min_ndim disambiguates MoE
# expert stacks ([L?, E, d, f], ndim>=3 under scan ndim 4) from dense FFN
# ([L?, d, f]): MoE archs always use the scan path, so their expert tensors
# are 4-D while stacked dense FFNs are 3-D.
_RULES = [
    # MoE experts: [E, d, f] / [E, f, d] — expert parallel over model
    (r"ffn/w_(gate|up|out)$", ("M", "F", None), 4),
    (r"router$", (None, None), 0),
    # attention projections [d, H*Dh] etc.
    (r"attn/w(q|k|v)$|cross/w(q|k|v)$", ("F", "M"), 0),
    (r"attn/wo$|cross/wo$", ("M", "F"), 0),
    (r"attn/b(q|k|v)$", ("M",), 0),
    # MLA
    (r"attn/w_dq$|attn/w_dkv$", ("F", None), 0),
    (r"attn/w_u(q|k|v)$", (None, "M"), 0),
    # dense FFN [d, f] / [f, d] (also MoE shared experts)
    (r"ffn/w_(gate|up)$|shared/\d+/w_(gate|up)$", ("F", "M"), 0),
    (r"ffn/w_out$|shared/\d+/w_out$", ("M", "F"), 0),
    (r"ffn/b_up$", ("M",), 0),
    (r"ffn/b_out$", (None,), 0),
    # SSM / recurrent
    (r"mamba/w_in$", ("F", "M"), 0),
    (r"mamba/w_out$", ("M", "F"), 0),
    (r"mamba/conv$", (None, "M"), 0),
    (r"mlstm/w(q|k|v)$|mlstm/wo_gate$", ("F", "M"), 0),
    (r"mlstm/w_out$", ("M", "F"), 0),
    (r"mlstm/w_if$", ("F", None), 0),
    (r"slstm/w_in$", ("F", "M"), 0),
    (r"slstm/w_out$", ("M", "F"), 0),
    (r"slstm/r$", (None, None, "M"), 0),
    # embeddings / head
    (r"^embed$", ("M", "F"), 0),
    (r"^head$", ("F", "M"), 0),
]


MODES = ("dp_tp", "fsdp_tp", "ddp_fsdp")


def _mode_axes(mesh: Mesh, mode: str):
    """(model_axis, fsdp_axes) per weight-sharding mode.

    dp_tp    — TP over `model`, no storage sharding (weights replicated
               across data): the classic baseline.
    fsdp_tp  — TP over `model` + ZeRO-3 storage sharding of the other weight
               dim over the data axes (all-gather at use).
    ddp_fsdp — NO tensor parallelism: batch over every mesh axis, weights
               ZeRO-3-sharded over all axes purely for storage. Kills the
               per-layer TP activation all-reduces (§Perf iteration 2)."""
    assert mode in MODES, mode
    if mode == "dp_tp":
        return "model", None
    if mode == "fsdp_tp":
        return "model", batch_axes(mesh)
    return None, tuple(mesh.axis_names)          # ddp_fsdp


def data_axes(mesh: Mesh, mode: str = "dp_tp") -> Tuple[str, ...]:
    """Axes the batch is sharded over for this mode."""
    return tuple(mesh.axis_names) if mode == "ddp_fsdp" else batch_axes(mesh)


def param_shardings(mesh: Mesh, params_shape: PyTree, mode: str = "dp_tp"
                    ) -> PyTree:
    """NamedSharding tree for a params (or eval_shape) tree."""
    model_axis, fsdp_axes = _mode_axes(mesh, mode)

    def one(path, leaf):
        name = _path_str(path)
        shape = tuple(leaf.shape)
        for pat, template, min_ndim in _RULES:
            if re.search(pat, name) and len(shape) >= min_ndim:
                tail = len(template)
                lead = len(shape) - tail
                if lead < 0:
                    break
                spec_axes = [None] * lead
                for t in template:
                    if t == "M":
                        spec_axes.append(model_axis)
                    elif t == "F":
                        spec_axes.append(fsdp_axes)
                    else:
                        spec_axes.append(None)
                return NamedSharding(mesh, _guard(mesh, P(*spec_axes), shape))
        return NamedSharding(mesh, P())       # norms, scalars: replicate
    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_shardings(mesh: Mesh, batch_shape: PyTree, mode: str = "dp_tp"
                    ) -> PyTree:
    """Training/prefill batch: leading batch dim over the mode's data axes."""
    baxes = data_axes(mesh, mode)

    def one(path, leaf):
        spec = _guard(mesh, P(baxes), tuple(leaf.shape))
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_shardings(mesh: Mesh, cache_shape: PyTree, global_batch: int
                    ) -> PyTree:
    """Decode cache: batch over data axes when divisible; otherwise (the
    long_500k single-request case) shard the cache SEQUENCE over data and
    heads over model. SSM states: batch over data, else heads over model."""
    baxes = batch_axes(mesh)
    batch_shardable = global_batch % _axis_size(mesh, baxes) == 0
    # single-axis specs as plain strings (P("data"), not P(("data",))) so
    # they render canonically; multi-axis stays a tuple
    baxes = baxes if len(baxes) > 1 else baxes[0]

    def one(path, leaf):
        name = _path_str(path)
        shape = tuple(leaf.shape)
        nd = len(shape)
        last = name.rsplit("/", 1)[-1]
        if last in ("k", "v"):                 # [(L,)B,S,Hkv,Dh]
            if batch_shardable:
                spec = [None] * (nd - 4) + [baxes, None, None, None]
            else:
                spec = [None] * (nd - 4) + [None, baxes, "model", None]
        elif last in ("ckv", "krope"):          # [(L,)B,S,R]
            if batch_shardable:
                spec = [None] * (nd - 3) + [baxes, None, None]
            else:
                spec = [None] * (nd - 3) + [None, baxes, None]
        elif last == "memory":                  # [B, Se, d]
            spec = [baxes if batch_shardable else None, None, None]
        elif last in ("ssm", "S"):              # [B, H, Dk, Dv]
            spec = ([baxes, None, None, None] if batch_shardable
                    else [None, "model", None, None])
        elif last in ("conv",):                 # [B, K-1, C]
            spec = ([baxes, None, None] if batch_shardable
                    else [None, None, "model"])
        elif last in ("h", "c", "n", "m"):      # sLSTM [B, H, Dh]
            spec = ([baxes, None, None] if batch_shardable
                    else [None, None, "model"])
        else:
            spec = [None] * nd
        return NamedSharding(mesh, _guard(mesh, P(*spec), shape))
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def replicated(mesh: Mesh, tree: PyTree) -> PyTree:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
