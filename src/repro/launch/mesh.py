"""Production meshes (TPU v5e). A FUNCTION, not a module constant — importing
this module never touches jax device state."""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2 pods x 256 = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Dev/test mesh over whatever devices exist (CPU: usually 1)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def batch_axes(mesh) -> Tuple[str, ...]:
    """Axes that shard the batch (data parallel, pod-extended)."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


# Hardware constants for the roofline (TPU v5e)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
