"""Step builders: train / prefill / decode, with full sharding specs.

``build(cfg, shape_name, mesh, mode)`` returns (jitted_fn, args_sds,
arg_shardings) ready for ``.lower(*args_sds).compile()`` — ShapeDtypeStructs
only, no allocation (the multi-pod dry-run path), and equally callable with
real arrays (the examples / integration tests path).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import ModelConfig, effective_config, input_specs
from repro.models.config import ModelConfig
from repro.models.inputs import SHAPES, enc_len_for
from repro.models.lm import init_cache, init_model, prefill_step, serve_step, train_loss
from repro.optim import adamw_init, adamw_update
from .sharding import (batch_shardings, cache_shardings, param_shardings,
                       replicated)

PyTree = Any


def make_train_step(cfg: ModelConfig, lr: float = 1e-4):
    def train_step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: train_loss(p, cfg, batch))(params)
        params, opt = adamw_update(grads, opt, params, lr, weight_decay=0.01)
        return params, opt, loss
    return train_step


def make_prefill_step(cfg: ModelConfig):
    def fn(params, batch):
        return prefill_step(params, cfg, batch)
    return fn


def make_decode_step(cfg: ModelConfig):
    def fn(params, tokens, cache, lengths):
        return serve_step(params, cfg, tokens, cache, lengths)
    return fn


def params_spec(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStruct tree of the model params (no allocation)."""
    return jax.eval_shape(
        functools.partial(init_model, cfg=cfg), jax.random.PRNGKey(0))


def build(cfg: ModelConfig, shape_name: str, mesh: Mesh,
          mode: str = "dp_tp", lr: float = 1e-4,
          shape_override=None):
    """Returns (jitted_fn, args_sds tuple, donate info) for the combo."""
    shape = shape_override or SHAPES[shape_name]
    cfg = effective_config(cfg, shape_name)
    p_sds = params_spec(cfg)
    p_sh = param_shardings(mesh, p_sds, mode)
    specs = input_specs(cfg, shape_name, shape=shape_override)

    if shape.kind == "train":
        o_sds = jax.eval_shape(adamw_init, p_sds)
        o_sh = type(o_sds)(step=NamedSharding(mesh, P()),
                           mu=param_shardings(mesh, o_sds.mu, mode),
                           nu=param_shardings(mesh, o_sds.nu, mode))
        b_sds = specs["batch"]
        b_sh = batch_shardings(mesh, b_sds, mode)
        fn = jax.jit(make_train_step(cfg, lr),
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, NamedSharding(mesh, P())),
                     donate_argnums=(0, 1))
        return fn, (p_sds, o_sds, b_sds)

    if shape.kind == "prefill":
        b_sds = specs["batch"]
        b_sh = batch_shardings(mesh, b_sds, mode)
        fn = jax.jit(make_prefill_step(cfg),
                     in_shardings=(p_sh, b_sh))
        return fn, (p_sds, b_sds)

    # decode
    t_sds = specs["tokens"]
    c_sds = specs["cache"]
    l_sds = specs["lengths"]
    c_sh = cache_shardings(mesh, c_sds, shape.global_batch)
    t_sh = replicated(mesh, t_sds)
    l_sh = replicated(mesh, l_sds)
    fn = jax.jit(make_decode_step(cfg),
                 in_shardings=(p_sh, t_sh, c_sh, l_sh),
                 out_shardings=(NamedSharding(mesh, P()), c_sh),
                 donate_argnums=(2,))
    return fn, (p_sds, t_sds, c_sds, l_sds)
