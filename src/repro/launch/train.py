"""Training driver.

Two workloads, selected by --workload:

* ``gnn`` (default) — THE PAPER: partition a graph with Leiden-Fusion (or a
  baseline via --partitioner), train one GNN per partition with zero
  communication, pool embeddings, train the MLP classifier, report accuracy.
* ``lm`` — train one of the assigned transformer architectures (--arch) on a
  synthetic token stream for --steps steps on the local mesh (CPU-scale dims
  come from ``--reduced``; the full configs are for the dry-run meshes).

Examples:
    PYTHONPATH=src python -m repro.launch.train --workload gnn \
        --partitioner leiden_fusion --k 8 --scheme repli --epochs 60
    PYTHONPATH=src python -m repro.launch.train --workload lm \
        --arch qwen3_4b --reduced --steps 20
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def train_gnn(args) -> dict:
    from repro.core import (build_partition_batch, evaluate_partition,
                            make_arxiv_like, make_proteins_like,
                            partition_from_spec)
    from repro.gnn import GNNConfig, train_classifier, train_local

    t0 = time.time()
    if args.dataset == "arxiv_like":
        ds = make_arxiv_like(n=args.nodes, seed=args.seed)
    else:
        ds = make_proteins_like(n=args.nodes or 6000, seed=args.seed)
    result = partition_from_spec(ds.graph, args.partitioner, args.k,
                                 seed=args.seed)
    labels, t_part = result.labels, result.seconds
    report = evaluate_partition(ds.graph, labels)
    batch = build_partition_batch(ds.graph, labels, scheme=args.scheme)
    cfg = GNNConfig(kind=args.model, feature_dim=ds.features.shape[1],
                    hidden_dim=args.hidden, embed_dim=args.hidden,
                    num_layers=3, dropout=args.dropout)
    t2 = time.time()
    params, emb = train_local(ds, batch, cfg, epochs=args.epochs,
                              lr=args.lr, seed=args.seed)
    t_train = time.time() - t2
    res = train_classifier(ds, emb, epochs=150, seed=args.seed)
    out = {
        "workload": "gnn", "dataset": ds.name, "partitioner": result.spec,
        "k": args.k, "scheme": args.scheme, "model": args.model,
        "partition_time_s": round(t_part, 2),
        "train_time_s": round(t_train, 2),
        "partition_quality": report.as_dict(),
        "metric": "rocauc" if ds.multilabel else "accuracy",
        "results": res,
        "total_s": round(time.time() - t0, 1),
    }
    if args.ckpt_dir:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt_dir, args.epochs, params)
        out["checkpoint"] = args.ckpt_dir
    return out


def train_lm(args) -> dict:
    from repro.configs import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_train_step
    from repro.models import init_model, make_batch
    from repro.optim import adamw_init

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    opt = adamw_init(params)
    batch = make_batch(cfg, batch=args.batch, seq=args.seq, seed=args.seed)
    step = jax.jit(make_train_step(cfg, lr=args.lr))
    losses = []
    t0 = time.time()
    for i in range(args.steps):
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    out = {
        "workload": "lm", "arch": cfg.name, "steps": args.steps,
        "first_loss": losses[0], "last_loss": losses[-1],
        "tokens_per_s": round(args.steps * args.batch * args.seq /
                              (time.time() - t0), 1),
    }
    if args.ckpt_dir:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt_dir, args.steps, params)
        out["checkpoint"] = args.ckpt_dir
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=["gnn", "lm"], default="gnn")
    # gnn
    ap.add_argument("--dataset", default="arxiv_like",
                    choices=["arxiv_like", "proteins_like"])
    ap.add_argument("--nodes", type=int, default=8000)
    ap.add_argument("--partitioner", default="leiden_fusion",
                    help="partitioner spec string, e.g. "
                         "\"lpa+f(alpha=0.1)\" (DESIGN.md §9)")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--scheme", default="repli", choices=["inner", "repli"])
    ap.add_argument("--model", default="gcn", choices=["gcn", "sage"])
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--dropout", type=float, default=0.3)
    ap.add_argument("--epochs", type=int, default=60)
    # lm
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    # common
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    out = train_gnn(args) if args.workload == "gnn" else train_lm(args)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
