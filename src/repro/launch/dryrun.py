import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary.
"""Multi-pod dry-run: lower + compile every (arch × input-shape) combination
on the production meshes, prove it fits and shards, and extract the roofline
terms from the compiled artifact.

Usage:
    python -m repro.launch.dryrun --arch qwen3_4b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--mode fsdp_tp]
    python -m repro.launch.dryrun --gnn            # the paper's own workload

Artifacts land in benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>__<mode>.json
"""
import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np


ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/artifacts/dryrun")


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def _compile_combo(cfg, shape_name, mesh, mode, fast: bool = False,
                   shape_override=None):
    """lower+compile one config; returns (compiled, lower_s, compile_s).

    ``fast`` compiles at backend optimization level 0 — used for the shallow
    cost-model lowerings only (cost_analysis numbers are identical; verified
    flops/hbm/collective bytes match the default pipeline bit-for-bit)."""
    from repro.launch.steps import build
    t0 = time.time()
    with mesh:
        fn, args_sds = build(cfg, shape_name, mesh, mode=mode,
                             shape_override=shape_override)
        lowered = fn.lower(*args_sds)
        t_lower = time.time() - t0
        opts = ({"xla_backend_optimization_level": 0} if fast else None)
        compiled = lowered.compile(compiler_options=opts)
    return compiled, t_lower, time.time() - t0 - t_lower


def _cost_terms(compiled) -> dict:
    from repro.launch.hlo_analysis import (collective_bytes,
                                           normalize_cost_analysis)
    ca = normalize_cost_analysis(compiled.cost_analysis())
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "hbm": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total"]), "coll_detail": coll}


def _depth_pair(cfg) -> tuple:
    """Two reduced depths (same block-pattern period) for the linear
    extrapolation flops(L) = a + b*L. See module docstring of
    repro.models.config (unroll) for why trip counts need this."""
    period = max(len(cfg.block_pattern), 1)
    base = max(cfg.first_k_dense, 0)
    l1 = base + period
    l2 = base + 2 * period
    return l1, l2


def _depth_extrapolate(cfg, shape_name, mesh, mode, shape_override=None):
    """term(L) = a + b*L from two shallow unrolled lowerings."""
    import dataclasses as dc
    l1, l2 = _depth_pair(cfg)
    enc_scale = cfg.encoder_layers / max(cfg.num_layers, 1)
    samples = {}
    for li in (l1, l2):
        c = dc.replace(cfg, num_layers=li, scan_layers=False, unroll=True,
                       encoder_layers=int(round(enc_scale * li)))
        compiled, _, _ = _compile_combo(c, shape_name, mesh, mode, fast=True,
                                        shape_override=shape_override)
        samples[li] = _cost_terms(compiled)
    full = cfg.num_layers
    out = {}
    for key in ("flops", "hbm", "coll"):
        y1, y2 = samples[l1][key], samples[l2][key]
        b = (y2 - y1) / (l2 - l1)
        out[key] = y1 + b * (full - l1)
    out["samples"] = {str(k): {kk: v[kk] for kk in ("flops", "hbm", "coll")}
                      for k, v in samples.items()}
    out["coll_detail_shallow"] = samples[l2]["coll_detail"]
    return out


def extrapolated_costs(cfg, shape_name, mesh, mode) -> dict:
    """Cost terms at full depth (and, for long-sequence heterogeneous archs,
    full sequence) from shallow UNROLLED lowerings.

    XLA's HloCostAnalysis counts while-loop bodies once, so the scanned
    full-depth module undercounts by ~num_layers. We lower the same config
    at depths L1 < L2 with every chunk loop unrolled and fit
    term(L) = a + b*L (exact for repeated identical layers).

    For block-pattern archs (zamba2/xlstm) at train/prefill seq >= 8k the
    unrolled chunk loops would produce intractable HLO (S/chunk * L chunk
    bodies), so we additionally sample three shorter sequences and fit the
    exact quadratic term(S) = a + b*S + c*S^2 (costs are polynomial in S:
    linear SSD chunk terms + quadratic attention) — both fits are exact for
    deterministic cost models, not statistical estimates."""
    import dataclasses as dc
    from repro.models.inputs import SHAPES, InputShape
    shape = SHAPES[shape_name]
    needs_seq_fit = (cfg.block_pattern and shape.kind in ("train", "prefill")
                     and shape.seq_len >= 8192)
    if not needs_seq_fit:
        return _depth_extrapolate(cfg, shape_name, mesh, mode)
    s_pts = (1024, 2048, 4096)
    fits = {}
    for s in s_pts:
        ov = InputShape(shape.name, s, shape.global_batch, shape.kind)
        fits[s] = _depth_extrapolate(cfg, shape_name, mesh, mode,
                                     shape_override=ov)
    out = {}
    for key in ("flops", "hbm", "coll"):
        ys = [fits[s][key] for s in s_pts]
        # exact quadratic through 3 points
        coef = np.polyfit(np.array(s_pts, dtype=np.float64), ys, 2)
        out[key] = float(np.polyval(coef, shape.seq_len))
    out["samples"] = {f"S{s}": fits[s]["samples"] for s in s_pts}
    out["coll_detail_shallow"] = fits[s_pts[-1]]["coll_detail_shallow"]
    out["seq_fit"] = True
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, mode: str,
            out_dir: str, verbose: bool = True,
            accurate: bool | None = None, tag: str = "",
            cfg_transform=None) -> dict:
    """Full-depth scanned lower+compile proves the combo shards and fits
    (memory_analysis); cost terms come from the depth-extrapolated unrolled
    lowerings when ``accurate`` (default on the single-pod mesh)."""
    from repro.configs import get_config
    from repro.launch.hlo_analysis import collective_bytes, roofline_terms
    from repro.launch.mesh import make_production_mesh
    from repro.models import effective_config
    from repro.models.inputs import SHAPES

    if accurate is None:
        accurate = not multi_pod
    cfg = get_config(arch)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    eff = effective_config(cfg, shape_name)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    record = {
        "arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod),
        "mode": mode, "chips": chips, "kind": shape.kind,
        "attention_variant": eff.attention, "accurate_costs": accurate,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    try:
        compiled, t_lower, t_compile = _compile_combo(cfg, shape_name, mesh,
                                                      mode)
        # ---- memory (full-depth module: while-loop buffers are real) ------
        try:
            ma = compiled.memory_analysis()
            mem = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
            }
        except Exception as e:                                # noqa: BLE001
            mem = {"error": str(e)}
        # ---- cost terms ----------------------------------------------------
        if accurate:
            costs = extrapolated_costs(cfg, shape_name, mesh, mode)
            flops, hbm, coll_total = costs["flops"], costs["hbm"], costs["coll"]
            record["cost_extrapolation"] = costs["samples"]
            record["collectives"] = costs["coll_detail_shallow"]
        else:
            terms0 = _cost_terms(compiled)
            flops, hbm, coll_total = (terms0["flops"], terms0["hbm"],
                                      terms0["coll"])
            record["collectives"] = terms0["coll_detail"]
        # ---- roofline ------------------------------------------------------
        terms = roofline_terms(flops, hbm, coll_total, chips)
        n_act = cfg.active_param_count()
        tokens = shape.global_batch * (shape.seq_len if shape.kind in
                                       ("train", "prefill") else 1)
        mf_mult = 6 if shape.kind == "train" else 2
        model_flops = mf_mult * n_act * tokens
        flops_global = flops * chips
        record.update({
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": mem, "flops_per_device": flops,
            "hbm_bytes_per_device": hbm, "collective_bytes": coll_total,
            "roofline": terms,
            "model_flops": model_flops,
            "useful_flops_frac": (model_flops / flops_global
                                  if flops_global else None),
            "ok": True,
        })
    except Exception as e:                                    # noqa: BLE001
        record.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]})
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{_mesh_tag(multi_pod)}__{mode}{tag}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=1, default=str)
    if verbose:
        status = "OK " if record["ok"] else "FAIL"
        extra = ""
        if record["ok"]:
            r = record["roofline"]
            extra = (f"compute={r['compute_s']:.2e}s "
                     f"mem={r['memory_s']:.2e}s coll={r['collective_s']:.2e}s"
                     f" dom={r['dominant']}")
        else:
            extra = record["error"][:160]
        print(f"[{status}] {arch:24s} {shape_name:12s} "
              f"{_mesh_tag(multi_pod):10s} {mode:7s} {extra}", flush=True)
    return record


def run_gnn_dryrun(multi_pod: bool, out_dir: str) -> dict:
    """The paper's own workload on the production mesh: one partition per
    chip, (a) LF local training — must be ZERO collectives — (b) the
    synchronized halo-exchange baseline — whose collective bytes quantify
    exactly the traffic the paper eliminates — and (c) the stale(period=N)
    middle ground: its exchange step moves the sync bytes, its
    between-exchange step must lower to zero (DESIGN.md §12)."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import (make_arxiv_like, leiden_fusion,
                            build_partition_batch, build_halo_exchange)
    from repro.gnn import (GNNConfig, gather_partition_tensors,
                           init_partition_models, make_local_train_step,
                           make_stale_train_steps, make_sync_train_step,
                           stale_bytes_per_epoch)
    from repro.launch.hlo_analysis import (collective_bytes,
                                           normalize_cost_analysis)
    from repro.launch.mesh import make_production_mesh
    from repro.optim import adamw_init

    mesh = make_production_mesh(multi_pod=multi_pod)
    k = int(np.prod(list(mesh.shape.values())))    # one partition per chip
    ds = make_arxiv_like(n=4096, feature_dim=128, seed=5)
    base_k = min(k, 64)
    labels = leiden_fusion(ds.graph, base_k, alpha=0.3)
    # build a k-partition batch by tiling (structure identical per partition)
    batch = build_partition_batch(ds.graph, labels, scheme="repli")
    halo = build_halo_exchange(ds.graph, labels, batch)
    reps = (k + batch.k - 1) // batch.k
    import dataclasses as dc
    tile = lambda a: np.concatenate([a] * reps, 0)[:k]
    batch = dc.replace(batch, node_ids=tile(batch.node_ids),
                       node_mask=tile(batch.node_mask),
                       owned_mask=tile(batch.owned_mask),
                       edge_src=tile(batch.edge_src),
                       edge_dst=tile(batch.edge_dst),
                       edge_weight=tile(batch.edge_weight),
                       in_degree=tile(batch.in_degree))
    # halo plan tiled to k partitions (peer indices stay within each block of
    # base_k partitions; good enough for a traffic-volume dry-run)
    halo_send = np.zeros((k, k, halo.h_pad), np.int32) - 1
    halo_recv = np.zeros((k, k, halo.h_pad), np.int32) - 1
    for r in range(reps):
        o = r * base_k
        if o + base_k <= k:
            halo_send[o:o + base_k, o:o + base_k] = halo.send_rows
            halo_recv[o:o + base_k, o:o + base_k] = halo.recv_rows
    halo = dc.replace(halo, send_rows=halo_send, recv_rows=halo_recv)
    pt = gather_partition_tensors(ds, batch)
    cfg = GNNConfig(kind="gcn", feature_dim=128, hidden_dim=256,
                    embed_dim=256, num_layers=3, dropout=0.0)
    p_sds = jax.eval_shape(
        lambda key: init_partition_models(key, cfg, ds.num_classes, k),
        jax.random.PRNGKey(0))
    o_sds = jax.eval_shape(lambda p: jax.vmap(adamw_init)(p), p_sds)
    tensors_sds = {n: jax.ShapeDtypeStruct(v.shape, v.dtype)
                   for n, v in {
                       "features": pt.features, "labels": pt.labels,
                       "train_mask": pt.train_mask, "edge_src": pt.edge_src,
                       "edge_dst": pt.edge_dst,
                       "edge_weight": pt.edge_weight,
                       "in_degree": pt.in_degree,
                       "node_mask": pt.node_mask}.items()}
    keys_sds = jax.ShapeDtypeStruct((k, 2), jnp.uint32)
    daxes = ("pod", "data") if multi_pod else ("data",)
    shard = NamedSharding(mesh, P(daxes))
    sh_tree = lambda t: jax.tree.map(lambda _: shard, t)
    record = {"workload": "gnn_lf_local", "mesh": _mesh_tag(multi_pod),
              "k_partitions": k, "n_pad": batch.n_pad, "e_pad": batch.e_pad,
              "halo_rows": int(halo.h_pad)}
    with mesh:
        step = jax.jit(make_local_train_step(cfg, False, 1e-2),
                       in_shardings=(sh_tree(p_sds), sh_tree(o_sds),
                                     sh_tree(tensors_sds), shard),
                       out_shardings=(sh_tree(p_sds), sh_tree(o_sds), shard))
        compiled = step.lower(p_sds, o_sds, tensors_sds, keys_sds).compile()
    coll = collective_bytes(compiled.as_text())
    ca = normalize_cost_analysis(compiled.cost_analysis())
    record.update({
        "collectives": coll,
        "flops_per_device": float(ca.get("flops", 0.0)),
        "zero_collectives": coll["total"] == 0,
        "ok": True,
    })
    # --- synchronized halo-exchange baseline (single-axis mesh only: the
    # shard_map step uses a flat "data" axis) ---------------------------------
    if not multi_pod:
        sync_mesh = jax.make_mesh((k,), ("data",))
        with sync_mesh:
            sync = make_sync_train_step(cfg, halo, False, sync_mesh, 1e-2)
            sync_compiled = sync.lower(p_sds, o_sds, tensors_sds,
                                       keys_sds).compile()
        sync_coll = collective_bytes(sync_compiled.as_text())
        record["sync_baseline_collectives"] = sync_coll
        record["communication_eliminated_bytes"] = sync_coll["total"]
        # --- stale(period=N): exchange step should match the sync traffic,
        # the between-exchange step must be collective-free -----------------
        from repro.gnn.train import _stale_cache_shapes
        with sync_mesh:
            steps = make_stale_train_steps(cfg, halo, False, sync_mesh, 1e-2)
            ex_compiled = steps["exchange"].lower(
                p_sds, o_sds, tensors_sds, keys_sds).compile()
            caches_sds = tuple(
                jax.ShapeDtypeStruct((k,) + s, jnp.float32)
                for s in _stale_cache_shapes(cfg, batch.n_pad))
            st_compiled = steps["stale"].lower(
                p_sds, o_sds, tensors_sds, keys_sds, caches_sds).compile()
        ex_coll = collective_bytes(ex_compiled.as_text())
        st_coll = collective_bytes(st_compiled.as_text())
        record["stale_exchange_collectives"] = ex_coll
        record["stale_step_collectives"] = st_coll
        record["stale_step_zero_collectives"] = st_coll["total"] == 0
        # the comm-vs-staleness frontier this mesh would see over 16 epochs
        record["stale_frontier_bytes_per_epoch"] = {
            str(p): int(np.mean(
                stale_bytes_per_epoch(ex_coll["total"], 16, p)))
            for p in (1, 2, 4, 8, 16)}
        # fair point-to-point lower bound (the all-gather implementation
        # over-fetches): actual halo rows x feature bytes x layers x fwd+bwd
        real_rows = int((halo_send >= 0).sum())
        record["halo_p2p_bytes_analytic"] = (
            real_rows * cfg.hidden_dim * 4 * cfg.num_layers * 2)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir,
                           f"gnn_lf__{_mesh_tag(multi_pod)}.json"), "w") as f:
        json.dump(record, f, indent=1, default=str)
    print(f"[OK ] gnn_lf_local {_mesh_tag(multi_pod)} "
          f"zero_collectives={record['zero_collectives']} "
          f"sync_bytes={record.get('communication_eliminated_bytes')} "
          f"stale_step_zero={record.get('stale_step_zero_collectives')}",
          flush=True)
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mode", type=str, default="dp_tp",
                    choices=["dp_tp", "fsdp_tp", "ddp_fsdp"])
    ap.add_argument("--gnn", action="store_true")
    ap.add_argument("--out", type=str, default=ARTIFACT_DIR)
    args = ap.parse_args()

    from repro.configs import ARCH_IDS
    from repro.models.inputs import SHAPES

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    if args.gnn:
        for mp in meshes:
            run_gnn_dryrun(mp, args.out)
        return 0
    if args.all:
        combos = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]
    for mp in meshes:
        for arch, shape in combos:
            rec = run_one(arch, shape, mp, args.mode, args.out)
            failures += 0 if rec["ok"] else 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
