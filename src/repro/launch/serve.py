"""Serving driver: bucketed prefill -> decode loop for any --arch.

A minimal but real continuous-batching loop: requests are grouped into
power-of-two prompt-length buckets, each bucket shares one padded prefill
and decodes in lock-step with per-request lengths; finished requests (EOS
or max tokens) exit the batch.

Bucketing replaces the old single shared prefill padded to the global max
prompt length: one 8-token request in a batch with one 512-token request no
longer pays a 512-wide prefill, and each bucket shape compiles exactly once
(counted in the output as ``prefill_compiles``/``decode_compiles`` — the
same measured-not-assumed discipline as ``repro.serving``'s CompileLog).

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm_125m --reduced \
        --requests 4 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

MIN_PREFILL_BUCKET = 8


def prefill_bucket(length: int) -> int:
    """Smallest power-of-two >= length (floored at MIN_PREFILL_BUCKET)."""
    b = MIN_PREFILL_BUCKET
    while b < length:
        b *= 2
    return b


def _compiles(fn) -> int:
    try:
        return fn._cache_size()
    except AttributeError:
        return -1          # private jit API unavailable: report unknown


def serve(args) -> dict:
    from repro.configs import get_config
    from repro.models import init_model, serve_step
    from repro.models.lm import grow_cache, prefill_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    params = init_model(jax.random.PRNGKey(args.seed), cfg)

    # synthetic request batch with ragged prompt lengths
    lengths = rng.integers(args.min_prompt, args.max_prompt + 1,
                           args.requests)
    buckets = np.array([prefill_bucket(int(s)) for s in lengths])

    prefill = jax.jit(lambda p, b: prefill_step(p, cfg, b))
    decode = jax.jit(lambda p, t, c, l: serve_step(p, cfg, t, c, l))

    gen = np.zeros((args.requests, args.max_new), dtype=np.int64)
    finite = True
    t_prefill = t_decode = 0.0
    bucket_counts: dict = {}
    # one padded prefill + lock-step decode per bucket: a fixed [g, s_b]
    # shape per group, so each bucket compiles once and a re-run with the
    # same bucket mix compiles nothing
    for s_b in sorted(set(buckets.tolist())):
        idx = np.where(buckets == s_b)[0]
        bucket_counts[int(s_b)] = int(idx.size)
        tokens = rng.integers(1, cfg.vocab_size, (idx.size, s_b))
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = jnp.asarray(
                rng.normal(0, 0.02, (idx.size, cfg.num_patch_tokens,
                                     cfg.d_model)), jnp.dtype(cfg.dtype))
        if cfg.frontend == "audio":
            batch["frames"] = jnp.asarray(
                rng.normal(0, 0.02, (idx.size, max(8, s_b // 8),
                                     cfg.d_model)), jnp.dtype(cfg.dtype))

        t0 = time.time()
        logits, cache, cache_len = prefill(params, batch)
        cache = grow_cache(cache, s_b + args.max_new)
        # per-request lengths start at the individual prompt length for
        # correct masking inside the bucket's shared padded prefill
        cur_len = jnp.asarray(lengths[idx], jnp.int32)
        t_prefill += time.time() - t0

        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        t1 = time.time()
        for step_i in range(args.max_new):
            gen[idx, step_i] = np.asarray(next_tok[:, 0])
            logits, cache = decode(params, next_tok, cache, cur_len)
            cur_len = cur_len + 1
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        t_decode += time.time() - t1
        finite = finite and bool(np.isfinite(np.asarray(logits)).all())

    return {
        "arch": cfg.name, "requests": args.requests,
        "prompt_lengths": lengths.tolist(),
        "prefill_buckets": {str(k): v
                            for k, v in sorted(bucket_counts.items())},
        "prefill_compiles": _compiles(prefill),
        "decode_compiles": _compiles(decode),
        "new_tokens": args.max_new,
        "prefill_s": round(t_prefill, 2),
        "decode_s": round(t_decode, 2),
        "decode_tok_per_s": round(args.requests * args.max_new /
                                  max(t_decode, 1e-9), 1),
        "finite": finite,
        "sample_generation": gen[0, :8].tolist(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print(json.dumps(serve(args), indent=1))


if __name__ == "__main__":
    main()
