"""Serving driver: batched prefill -> decode loop for any --arch.

A minimal but real continuous-batching loop: requests with different prompt
lengths share one padded prefill, then decode in lock-step with per-request
lengths; finished requests (EOS or max tokens) exit the batch.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm_125m --reduced \
        --requests 4 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve(args) -> dict:
    from repro.configs import get_config
    from repro.models import init_model, serve_step
    from repro.models.lm import grow_cache, prefill_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    params = init_model(jax.random.PRNGKey(args.seed), cfg)

    # synthetic request batch with ragged prompt lengths, left-padded to max
    lengths = rng.integers(args.min_prompt, args.max_prompt + 1,
                           args.requests)
    s_max = int(lengths.max())
    tokens = rng.integers(1, cfg.vocab_size, (args.requests, s_max))
    batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (args.requests, cfg.num_patch_tokens,
                                 cfg.d_model)), jnp.dtype(cfg.dtype))
    if cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (args.requests, max(8, s_max // 8),
                                 cfg.d_model)), jnp.dtype(cfg.dtype))

    prefill = jax.jit(lambda p, b: prefill_step(p, cfg, b))
    decode = jax.jit(lambda p, t, c, l: serve_step(p, cfg, t, c, l))

    t0 = time.time()
    logits, cache, cache_len = prefill(params, batch)
    cache = grow_cache(cache, s_max + args.max_new)
    # NOTE: shared prefill pads every request to s_max; per-request lengths
    # start at the individual prompt length for correct masking.
    cur_len = jnp.asarray(lengths, jnp.int32)
    t_prefill = time.time() - t0

    out_tokens = []
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    t1 = time.time()
    for step_i in range(args.max_new):
        out_tokens.append(np.asarray(next_tok[:, 0]))
        logits, cache = decode(params, next_tok, cache, cur_len)
        cur_len = cur_len + 1
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    t_decode = time.time() - t1

    gen = np.stack(out_tokens, 1)
    return {
        "arch": cfg.name, "requests": args.requests,
        "prompt_lengths": lengths.tolist(),
        "new_tokens": args.max_new,
        "prefill_s": round(t_prefill, 2),
        "decode_s": round(t_decode, 2),
        "decode_tok_per_s": round(args.requests * args.max_new /
                                  max(t_decode, 1e-9), 1),
        "finite": bool(np.isfinite(np.asarray(logits)).all()),
        "sample_generation": gen[0, :8].tolist(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print(json.dumps(serve(args), indent=1))


if __name__ == "__main__":
    main()
