"""Multi-layer GNN (GCN / GraphSAGE) + MLP classifier head.

The GNN body produces node *embeddings* (paper: embeddings from local
training are pooled and an MLP classifier is trained on them)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from .layers import (gcn_layer, init_gcn_layer, init_sage_layer, sage_layer)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    kind: str = "gcn"              # "gcn" | "sage"
    feature_dim: int = 128
    hidden_dim: int = 256
    embed_dim: int = 256           # output embedding size
    num_layers: int = 3
    dropout: float = 0.5
    use_kernel: bool = False       # route aggregation through Pallas kernel


def init_gnn(key, cfg: GNNConfig) -> PyTree:
    dims = ([cfg.feature_dim] + [cfg.hidden_dim] * (cfg.num_layers - 1)
            + [cfg.embed_dim])
    keys = jax.random.split(key, cfg.num_layers)
    init = init_gcn_layer if cfg.kind == "gcn" else init_sage_layer
    return {"layers": [init(keys[i], dims[i], dims[i + 1])
                       for i in range(cfg.num_layers)]}


def gnn_forward(params: PyTree, cfg: GNNConfig, features: jnp.ndarray,
                edge_src, edge_dst, edge_weight, in_degree,
                node_mask: Optional[jnp.ndarray] = None,
                dropout_key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Run the GNN body; returns [N, embed_dim] embeddings."""
    layer = gcn_layer if cfg.kind == "gcn" else sage_layer
    h = features
    if node_mask is not None:
        h = h * node_mask[:, None]          # zero padded rows
    n_layers = len(params["layers"])
    for i, lp in enumerate(params["layers"]):
        last = i == n_layers - 1
        h = layer(lp, h, edge_src, edge_dst, edge_weight, in_degree,
                  activate=not last, use_kernel=cfg.use_kernel)
        if node_mask is not None:
            h = h * node_mask[:, None]
        if dropout_key is not None and cfg.dropout > 0 and not last:
            dropout_key, sub = jax.random.split(dropout_key)
            keep = jax.random.bernoulli(sub, 1 - cfg.dropout, h.shape)
            h = jnp.where(keep, h / (1 - cfg.dropout), 0.0)
    return h


def head_logits(head: PyTree, emb: jnp.ndarray) -> jnp.ndarray:
    """Per-partition linear head on embeddings: ``emb @ w + b``.

    The single-node inference entry the serving layer shares with training
    (`_forward_one`, `make_halo_forward`): ``head`` is one partition's
    ``{"w": [E, C], "b": [C]}`` slice of the stacked params."""
    return emb @ head["w"] + head["b"]


# ---------------------------------------------------------------------------
# MLP classifier on pooled embeddings
# ---------------------------------------------------------------------------
def init_mlp(key, in_dim: int, hidden: int, out_dim: int) -> PyTree:
    k1, k2 = jax.random.split(key)
    s1, s2 = jnp.sqrt(2.0 / in_dim), jnp.sqrt(2.0 / hidden)
    return {"w1": jax.random.normal(k1, (in_dim, hidden)) * s1,
            "b1": jnp.zeros((hidden,)),
            "w2": jax.random.normal(k2, (hidden, out_dim)) * s2,
            "b2": jnp.zeros((out_dim,))}


def mlp_forward(params: PyTree, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)


def sigmoid_bce(logits: jnp.ndarray, targets: jnp.ndarray,
                mask: jnp.ndarray) -> jnp.ndarray:
    per = jnp.maximum(logits, 0) - logits * targets + jnp.log1p(
        jnp.exp(-jnp.abs(logits)))
    per = per.mean(axis=-1)
    return jnp.sum(per * mask) / jnp.maximum(mask.sum(), 1.0)
