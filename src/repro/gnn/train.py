"""Distributed GNN training — the paper's pipeline, SPMD-native.

Three training modes over the k partition subgraphs:

* **local** (the paper's contribution): every partition trains its own GNN
  replica with NO inter-partition communication. Implemented as a vmap over
  the stacked partition axis; under `jit` with the partition axis sharded
  over the mesh `data` axis this is embarrassingly parallel — the lowered
  HLO contains zero collectives (asserted in tests / measured in §Roofline).

* **sync** (the DGL-style baseline the paper argues against): identical
  model, but before every GNN layer the halo rows are refreshed from their
  owner partitions via an `all_gather` over the `data` axis inside
  `shard_map`. The collective bytes this injects are exactly the paper's
  "continuous communication".

* **stale** (the middle ground, DESIGN.md §12): the same `shard_map` halo
  plumbing as sync, but boundary activations are exchanged only every
  ``sync_period`` epochs; in between, layers read the *frozen* halo rows
  cached at the last exchange — zero collectives on those epochs. The two
  limit cases reduce exactly to the modes above (``period=1`` ≡ sync,
  ``period=∞`` ≡ local) and are pinned by `tests/test_stale_mode.py`.

After training, per-partition embeddings of *owned* nodes are scattered back
into a global [n, embed] table and an MLP classifier is trained on it
(paper §5.2). An optional *integration* step (`repro.core.assemble.
integrate_models`) parameter-averages or ensembles the k replicas first."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.core import NodeDataset, PartitionBatch, HaloExchangeSpec
from repro.optim import OptState, adamw_init, adamw_update
from .model import (GNNConfig, gnn_forward, head_logits, init_gnn, init_mlp,
                    mlp_forward, sigmoid_bce, softmax_xent)

PyTree = Any


def _finish_epoch_span(sp, loss) -> None:
    """Tracing-enabled epoch bookkeeping: block on the dispatched step so
    the span covers the actual device compute (JAX dispatch is async — an
    unblocked epoch span would time only the Python enqueue), then record
    the realized mean loss on the span and the registry gauge. Only called
    under ``obs.enabled()`` — the ``float()`` forces a device sync the
    disabled path must never pay."""
    val = float(jnp.mean(jax.block_until_ready(loss)))
    sp.set(loss=round(val, 6))
    obs.gauge("train.loss").set(val)


# ---------------------------------------------------------------------------
# Per-partition tensors (host-side assembly)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PartitionTensors:
    """Stacked per-partition training arrays, leading axis k."""
    features: np.ndarray      # [k, N_pad, F]
    labels: np.ndarray        # [k, N_pad] int32 or [k, N_pad, T] f32
    train_mask: np.ndarray    # [k, N_pad] f32 (owned & train & valid)
    edge_src: np.ndarray      # [k, E_pad]
    edge_dst: np.ndarray
    edge_weight: np.ndarray
    in_degree: np.ndarray
    node_mask: np.ndarray     # [k, N_pad] f32
    owned_mask: np.ndarray    # [k, N_pad] bool
    node_ids: np.ndarray      # [k, N_pad] int32


def gather_partition_tensors(ds: NodeDataset, batch: PartitionBatch
                             ) -> PartitionTensors:
    ids = np.maximum(batch.node_ids, 0)
    feats = ds.features[ids] * batch.node_mask[..., None]
    labels = ds.labels[ids]
    if not ds.multilabel:
        labels = labels.astype(np.int32)
    train = ds.train_mask[ids] & batch.owned_mask & batch.node_mask
    return PartitionTensors(
        features=feats.astype(np.float32),
        labels=labels,
        train_mask=train.astype(np.float32),
        edge_src=batch.edge_src, edge_dst=batch.edge_dst,
        edge_weight=batch.edge_weight, in_degree=batch.in_degree,
        node_mask=batch.node_mask.astype(np.float32),
        owned_mask=batch.owned_mask, node_ids=batch.node_ids)


# ---------------------------------------------------------------------------
# Model+head params
# ---------------------------------------------------------------------------
def init_partition_models(key, cfg: GNNConfig, num_classes: int, k: int
                          ) -> PyTree:
    """k independent GNN+head replicas, stacked on axis 0."""
    def one(subkey):
        kb, kh = jax.random.split(subkey)
        body = init_gnn(kb, cfg)
        s = jnp.sqrt(2.0 / cfg.embed_dim)
        head = {"w": jax.random.normal(kh, (cfg.embed_dim, num_classes)) * s,
                "b": jnp.zeros((num_classes,))}
        return {"body": body, "head": head}
    return jax.vmap(one)(jax.random.split(key, k))


def _forward_one(params, cfg: GNNConfig, t: Dict[str, jnp.ndarray],
                 dropout_key=None, halo_refresh: Optional[Callable] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Forward for ONE partition. Returns (embeddings, logits)."""
    feats = t["features"]
    if halo_refresh is not None:
        feats = halo_refresh(feats, layer_idx=0)
    emb = gnn_forward(params["body"], cfg, feats, t["edge_src"],
                      t["edge_dst"], t["edge_weight"], t["in_degree"],
                      node_mask=t["node_mask"], dropout_key=dropout_key)
    return emb, head_logits(params["head"], emb)


def _loss_one(params, cfg: GNNConfig, t, multilabel: bool, dropout_key):
    _, logits = _forward_one(params, cfg, t, dropout_key)
    if multilabel:
        return sigmoid_bce(logits, t["labels"], t["train_mask"])
    return softmax_xent(logits, t["labels"], t["train_mask"])


def _tensors_dict(pt: PartitionTensors) -> Dict[str, np.ndarray]:
    return {"features": pt.features, "labels": pt.labels,
            "train_mask": pt.train_mask, "edge_src": pt.edge_src,
            "edge_dst": pt.edge_dst, "edge_weight": pt.edge_weight,
            "in_degree": pt.in_degree, "node_mask": pt.node_mask}


# ---------------------------------------------------------------------------
# LOCAL training (the paper's scheme — zero collectives)
# ---------------------------------------------------------------------------
def make_local_train_step(cfg: GNNConfig, multilabel: bool, lr: float = 1e-2,
                          per_partition: bool = False) -> Callable:
    """Returns jit-able step(params, opt, tensors, key) -> (params, opt, loss).

    All arrays carry a leading k axis; the step is a pure vmap — sharding the
    k axis over `data` makes it fully local per device. With
    ``per_partition=True`` the un-vmapped single-partition step is returned
    instead (no leading k axis) — the low-memory sequential path trains one
    partition at a time with it, and since local-mode partitions never
    interact, the math per partition is the same either way."""
    def one_step(params, opt, t, key):
        loss, grads = jax.value_and_grad(_loss_one)(params, cfg, t,
                                                    multilabel, key)
        params, opt = adamw_update(grads, opt, params, lr, weight_decay=0.0)
        return params, opt, loss

    if per_partition:
        return one_step

    def step(params, opt, tensors, keys):
        return jax.vmap(one_step)(params, opt, tensors, keys)
    return step


def train_local(ds: NodeDataset, batch: PartitionBatch, cfg: GNNConfig,
                epochs: int = 60, lr: float = 1e-2, seed: int = 0,
                mesh: Optional[Mesh] = None,
                hlo_out: Optional[Dict[str, str]] = None,
                integrate: str = "none", sequential: bool = False
                ) -> Tuple[PyTree, np.ndarray]:
    """Paper's local training. Returns (params, global_embeddings [n, E]).

    When ``hlo_out`` is given, the optimized (post-SPMD) HLO of the train
    step is stored under ``hlo_out["hlo"]`` so callers (the pipeline report,
    the roofline benchmark) can count collective bytes — for this mode the
    count is zero, which is the paper's claim.

    ``sequential=True`` (the pipeline's ``low_memory`` flag, DESIGN.md §15)
    trains the k partitions one at a time through the un-vmapped step
    instead of all at once: the vmapped step materializes every partition's
    ``[E_pad, F]`` edge gathers simultaneously (~k times the transient
    footprint — ~18 GB at n=1e6, k=8, F=128 measured), the sequential loop
    only ever one. Partitions never interact in local mode and the
    per-epoch dropout keys are the same ``keys[p]``, so the trained
    parameters and embeddings are identical to the vmapped path
    (pinned in tests/test_graphstore.py). Requires an unsharded run
    (``mesh is None``) and no ``hlo_out``."""
    if sequential and mesh is None and hlo_out is None:
        return _train_local_sequential(ds, batch, cfg, epochs=epochs, lr=lr,
                                       seed=seed, integrate=integrate)
    pt = gather_partition_tensors(ds, batch)
    k = batch.k
    num_out = ds.num_classes
    key = jax.random.PRNGKey(seed)
    params = init_partition_models(key, cfg, num_out, k)
    opt = jax.vmap(adamw_init)(params)   # per-partition opt state (step: [k])
    tensors = {n: jnp.asarray(v) for n, v in _tensors_dict(pt).items()}

    step = make_local_train_step(cfg, ds.multilabel, lr)
    if mesh is not None:
        shard = NamedSharding(mesh, P("data"))
        step = jax.jit(step, in_shardings=(shard, shard, shard, shard),
                       out_shardings=(shard, shard, shard))
    else:
        step = jax.jit(step)

    if hlo_out is not None:
        # AOT-compile once and reuse the executable for stepping — the AOT
        # path does not populate the jit cache, so calling `step` afterwards
        # would compile a second time.
        keys0 = jax.random.split(jax.random.fold_in(key, 0), k)
        compiled = step.lower(params, opt, tensors, keys0).compile()
        hlo_out["hlo"] = compiled.as_text()
        step = compiled

    epochs_ctr = obs.counter("train.epochs")
    traced = obs.enabled()
    for e in range(epochs):
        keys = jax.random.split(jax.random.fold_in(key, e), k)
        if traced:
            with obs.span("train.epoch", epoch=e, mode="local") as sp:
                params, opt, loss = step(params, opt, tensors, keys)
                _finish_epoch_span(sp, loss)
        else:
            params, opt, loss = step(params, opt, tensors, keys)
        epochs_ctr.inc()
    params, emb = apply_integration(
        params, integrate, lambda p: compute_embeddings(p, cfg, tensors), k)
    return params, pool_embeddings(np.asarray(emb), pt, ds.graph.n,
                                   cfg.embed_dim)


def _train_local_sequential(ds: NodeDataset, batch: PartitionBatch,
                            cfg: GNNConfig, epochs: int, lr: float,
                            seed: int, integrate: str
                            ) -> Tuple[PyTree, np.ndarray]:
    """Low-memory local training: one partition at a time (see train_local).

    The epoch/partition loops are swapped relative to the vmapped path —
    partition p runs all its epochs before p+1 starts — which is legal
    exactly because local training has no cross-partition dataflow. Only
    one partition's tensors are resident on device at a time; the jitted
    single-partition step compiles once (padding makes every partition the
    same shape)."""
    pt = gather_partition_tensors(ds, batch)
    k = batch.k
    np_tensors = _tensors_dict(pt)
    key = jax.random.PRNGKey(seed)
    params = init_partition_models(key, cfg, ds.num_classes, k)
    # per-epoch key schedule, identical to the vmapped path's
    ep_keys = [jax.random.split(jax.random.fold_in(key, e), k)
               for e in range(epochs)]
    step1 = jax.jit(make_local_train_step(cfg, ds.multilabel, lr,
                                          per_partition=True))
    epochs_ctr = obs.counter("train.epochs")
    traced = obs.enabled()
    trained: List[PyTree] = []
    for p in range(k):
        t_p = {name: jnp.asarray(v[p]) for name, v in np_tensors.items()}
        params_p = jax.tree.map(lambda x: x[p], params)
        opt_p = adamw_init(params_p)
        with obs.span("train.partition", partition=p, epochs=epochs,
                      mode="local_sequential") as psp:
            loss = None
            for e in range(epochs):
                params_p, opt_p, loss = step1(params_p, opt_p, t_p,
                                              ep_keys[e][p])
                epochs_ctr.inc()
            if traced and loss is not None:
                _finish_epoch_span(psp, loss)
        trained.append(jax.tree.map(np.asarray, params_p))
        del t_p, params_p, opt_p
    params = jax.tree.map(lambda *xs: jnp.stack(xs), *trained)

    fwd1 = jax.jit(lambda pp, t: _forward_one(pp, cfg, t)[0])

    def emb_fn(ps):
        out = []
        for p in range(k):
            t_p = {name: jnp.asarray(v[p]) for name, v in np_tensors.items()}
            out.append(np.asarray(fwd1(jax.tree.map(lambda x: x[p], ps),
                                       t_p)))
            del t_p
        return jnp.asarray(np.stack(out))

    params, emb = apply_integration(params, integrate, emb_fn, k)
    return params, pool_embeddings(np.asarray(emb), pt, ds.graph.n,
                                   cfg.embed_dim)


def compute_embeddings(params, cfg: GNNConfig, tensors) -> jnp.ndarray:
    def one(p, t):
        emb, _ = _forward_one(p, cfg, t)
        return emb
    return jax.jit(jax.vmap(one))(params, tensors)


def apply_integration(params, integrate: Optional[str],
                      emb_fn: Callable[[Any], jnp.ndarray], k: int
                      ) -> Tuple[PyTree, jnp.ndarray]:
    """Integrate the k per-partition models before embedding assembly.

    ``emb_fn(params) -> [k, N_pad, E]`` is the mode's own embedding forward
    (plain vmap for local, halo-refreshing shard_map for sync/stale), so the
    integration step composes with every training mode.

    - ``"none"``      — k independent models, as trained (the paper).
    - ``"model_avg"`` — parameter-average the replicas
      (:func:`repro.core.assemble.average_partition_params`; randomized-
      partition model aggregation, arxiv 2305.09887) and embed with the
      averaged model everywhere.
    - ``"ensemble"``  — keep the k models but embed each subgraph with ALL
      of them and average the embeddings (prediction-level aggregation).
    """
    from repro.core.assemble import average_partition_params
    if integrate in (None, "none"):
        return params, emb_fn(params)
    if integrate == "model_avg":
        params = average_partition_params(params)
        return params, emb_fn(params)
    if integrate == "ensemble":
        acc = None
        for m in range(k):
            pm = jax.tree.map(
                lambda x: jnp.broadcast_to(x[m:m + 1], x.shape), params)
            emb = emb_fn(pm)
            acc = emb if acc is None else acc + emb
        return params, acc / float(k)
    raise ValueError(
        f"integrate must be none|model_avg|ensemble, got {integrate!r}")


def pool_embeddings(emb: np.ndarray, pt: PartitionTensors, n: int,
                    embed_dim: int) -> np.ndarray:
    """Scatter owned-node embeddings back to a global [n, E] table."""
    out = np.zeros((n, embed_dim), dtype=np.float32)
    for p in range(emb.shape[0]):
        owned = pt.owned_mask[p]
        ids = pt.node_ids[p][owned]
        out[ids] = emb[p][owned]
    return out


# ---------------------------------------------------------------------------
# Halo-refreshing forward, shared by the SYNC baseline and STALE mode.
#
# Three refresh disciplines over the same `shard_map` halo plumbing:
#   "exchange" — live all_gather before every layer (sync semantics); the
#                post-refresh activations are returned as per-layer caches
#   "cached"   — halo rows overwritten from the caches of the last exchange
#                epoch (zero collectives; the staleness of DESIGN.md §12)
#   "frozen"   — no refresh at all: halo rows stay whatever local compute
#                produces, which is exactly `gnn_forward` (local semantics)
# ---------------------------------------------------------------------------
def make_halo_forward(cfg: GNNConfig, halo: HaloExchangeSpec,
                      axis: str = "data"):
    """Build ``forward(params, t, my_idx, dropout_key, caches, refresh_mode)``
    for use inside shard_map (one partition per ``axis`` device).

    Returns ``(embeddings, logits, new_caches)`` where ``new_caches`` is the
    tuple of post-refresh layer inputs under ``refresh_mode="exchange"`` and
    ``None`` otherwise.

    ``dropout_key`` mirrors :func:`repro.gnn.model.gnn_forward` exactly
    (dropout after every non-final layer at rate ``cfg.dropout``), so every
    halo mode consumes the training config identically to local mode —
    earlier revisions silently trained the sync baseline without dropout, an
    unfair comparison in the paper's favor. Pass ``None`` for inference."""
    send_rows = jnp.asarray(halo.send_rows)   # [k, k, H]
    recv_rows = jnp.asarray(halo.recv_rows)   # [k, k, H]

    def refresh(h: jnp.ndarray, my_idx: jnp.ndarray) -> jnp.ndarray:
        # Build what I send to every peer: rows of my h.  [k, H, F]
        mine_send = send_rows[my_idx]                       # [k, H]
        buf = h[jnp.maximum(mine_send, 0)] * (mine_send >= 0)[..., None]
        allbuf = jax.lax.all_gather(buf, axis)              # [k, k, H, F]
        # What peer q sent to me sits at allbuf[q, my_idx]
        incoming = allbuf[:, my_idx]                        # [k, H, F]
        rows = recv_rows[my_idx]                            # [k, H]
        flat_rows = rows.reshape(-1)
        flat_in = incoming.reshape(-1, h.shape[-1])
        valid = (flat_rows >= 0)[:, None]
        h = h.at[jnp.maximum(flat_rows, 0)].set(
            jnp.where(valid, flat_in, h[jnp.maximum(flat_rows, 0)]))
        return h

    def apply_cache(h: jnp.ndarray, my_idx: jnp.ndarray,
                    cache: jnp.ndarray) -> jnp.ndarray:
        # Overwrite exactly the rows a live exchange would refresh, but from
        # the frozen snapshot instead of the wire — no collective lowered.
        rows = recv_rows[my_idx].reshape(-1)
        safe = jnp.maximum(rows, 0)
        valid = (rows >= 0)[:, None]
        h = h.at[safe].set(jnp.where(valid, cache[safe], h[safe]))
        return h

    from .layers import gcn_layer, sage_layer
    layer_fn = gcn_layer if cfg.kind == "gcn" else sage_layer

    def forward(params, t, my_idx, dropout_key=None, caches=None,
                refresh_mode: str = "exchange"):
        assert refresh_mode in ("exchange", "cached", "frozen"), refresh_mode
        h = t["features"] * t["node_mask"][:, None]
        n_layers = len(params["body"]["layers"])
        new_caches = []
        for i, lp in enumerate(params["body"]["layers"]):
            last = i == n_layers - 1
            if refresh_mode == "exchange":
                h = refresh(h, my_idx)    # fetch fresh halo activations
                new_caches.append(h)      # snapshot for the stale epochs
            elif refresh_mode == "cached":
                h = apply_cache(h, my_idx, caches[i])
            h = layer_fn(lp, h, t["edge_src"], t["edge_dst"],
                         t["edge_weight"], t["in_degree"],
                         activate=not last, use_kernel=cfg.use_kernel)
            h = h * t["node_mask"][:, None]
            if dropout_key is not None and cfg.dropout > 0 and not last:
                dropout_key, sub = jax.random.split(dropout_key)
                keep = jax.random.bernoulli(sub, 1 - cfg.dropout, h.shape)
                h = jnp.where(keep, h / (1 - cfg.dropout), 0.0)
        logits = head_logits(params["head"], h)
        caches_out = tuple(new_caches) if refresh_mode == "exchange" else None
        return h, logits, caches_out
    return forward


# ---------------------------------------------------------------------------
# SYNC baseline (halo exchange every layer — the traffic LF eliminates)
# ---------------------------------------------------------------------------
def make_sync_forward(cfg: GNNConfig, halo: HaloExchangeSpec, axis: str = "data"):
    """Forward with live halo refresh before every layer (sync semantics).

    Thin wrapper over :func:`make_halo_forward` with
    ``refresh_mode="exchange"``, kept for API stability — returns
    ``(embeddings, logits)``."""
    halo_forward = make_halo_forward(cfg, halo, axis)

    def forward(params, t, my_idx, dropout_key=None):
        h, logits, _ = halo_forward(params, t, my_idx, dropout_key,
                                    refresh_mode="exchange")
        return h, logits
    return forward


def make_sync_train_step(cfg: GNNConfig, halo: HaloExchangeSpec,
                         multilabel: bool, mesh: Mesh, lr: float = 1e-2):
    """shard_map train step: one partition per `data` device."""
    from jax.experimental.shard_map import shard_map
    forward = make_sync_forward(cfg, halo)

    def loss_fn(params, t, my_idx, dropout_key):
        _, logits = forward(params, t, my_idx, dropout_key)
        if multilabel:
            loss = sigmoid_bce(logits, t["labels"], t["train_mask"])
        else:
            loss = softmax_xent(logits, t["labels"], t["train_mask"])
        return loss

    def local_step(params, opt, t, keys):
        # leading axis is the local shard of k: size 1 per device
        params1 = jax.tree.map(lambda x: x[0], params)
        opt1 = jax.tree.map(lambda x: x[0], opt)
        t1 = jax.tree.map(lambda x: x[0], t)
        my_idx = jax.lax.axis_index("data")
        loss, grads = jax.value_and_grad(loss_fn)(params1, t1, my_idx,
                                                  keys[0])
        new_p, new_o = adamw_update(grads, opt1, params1, lr)
        expand = lambda x: x[None]
        return (jax.tree.map(expand, new_p), jax.tree.map(expand, new_o),
                loss[None])

    pspec = P("data")
    # check_rep=False: pallas_call (the use_kernel aggregation path) has no
    # shard_map replication rule; all inputs/outputs are explicitly sharded
    # over `data`, so the check is vacuous here anyway
    step = shard_map(local_step, mesh=mesh,
                     in_specs=(pspec, pspec, pspec, pspec),
                     out_specs=(pspec, pspec, pspec), check_rep=False)
    return jax.jit(step)


def train_sync(ds: NodeDataset, batch: PartitionBatch,
               halo: HaloExchangeSpec, cfg: GNNConfig, mesh: Mesh,
               epochs: int = 60, lr: float = 1e-2, seed: int = 0,
               hlo_out: Optional[Dict[str, str]] = None,
               integrate: str = "none"
               ) -> Tuple[PyTree, np.ndarray]:
    """DGL-style synchronized baseline, mirroring :func:`train_local`.

    Requires a mesh whose ``data`` axis size equals the partition count
    (one partition per device); every layer refreshes halo activations via
    an all_gather, which is exactly the traffic Leiden-Fusion eliminates.
    Returns (params, global_embeddings [n, E])."""
    from jax.experimental.shard_map import shard_map

    k = batch.k
    data_size = int(mesh.shape["data"])
    if data_size != k:
        raise ValueError(
            f"sync training needs one partition per device: mesh data axis "
            f"is {data_size} but k={k}. On CPU, relaunch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={k}.")
    pt = gather_partition_tensors(ds, batch)
    key = jax.random.PRNGKey(seed)
    params = init_partition_models(key, cfg, ds.num_classes, k)
    opt = jax.vmap(adamw_init)(params)
    tensors = {n: jnp.asarray(v) for n, v in _tensors_dict(pt).items()}

    step = make_sync_train_step(cfg, halo, ds.multilabel, mesh, lr)
    if hlo_out is not None:
        keys0 = jax.random.split(jax.random.fold_in(key, 0), k)
        compiled = step.lower(params, opt, tensors, keys0).compile()
        hlo_out["hlo"] = compiled.as_text()
        step = compiled
    epochs_ctr = obs.counter("train.epochs")
    traced = obs.enabled()
    for e in range(epochs):
        keys = jax.random.split(jax.random.fold_in(key, e), k)
        if traced:
            with obs.span("train.epoch", epoch=e, mode="sync") as sp:
                params, opt, loss = step(params, opt, tensors, keys)
                _finish_epoch_span(sp, loss)
        else:
            params, opt, loss = step(params, opt, tensors, keys)
        epochs_ctr.inc()

    forward = make_sync_forward(cfg, halo)

    def eval_one(p, t):
        p1 = jax.tree.map(lambda x: x[0], p)
        t1 = jax.tree.map(lambda x: x[0], t)
        emb, _ = forward(p1, t1, jax.lax.axis_index("data"))
        return emb[None]

    pspec = P("data")
    emb_fn = jax.jit(shard_map(eval_one, mesh=mesh, in_specs=(pspec, pspec),
                               out_specs=pspec, check_rep=False))
    params, emb = apply_integration(
        params, integrate, lambda p: emb_fn(p, tensors), k)
    return params, pool_embeddings(np.asarray(emb), pt, ds.graph.n,
                                   cfg.embed_dim)


# ---------------------------------------------------------------------------
# STALE mode (periodic halo exchange — the comm-vs-accuracy middle ground)
# ---------------------------------------------------------------------------
def stale_exchange_epochs(epochs: int, period: Optional[int]) -> List[int]:
    """Epochs at which stale mode performs a live halo exchange.

    ``period >= 1`` exchanges at every epoch ``e`` with ``e % period == 0``
    (epoch 0 always exchanges); ``period`` in ``{None, 0}`` or negative
    means *never* exchange — the ``stale(∞)`` limit that reduces to local
    training. ``period=1`` exchanges every epoch — the sync limit."""
    if not period or period < 1:
        return []
    return [e for e in range(epochs) if e % period == 0]


def stale_bytes_per_epoch(exchange_bytes: int, epochs: int,
                          period: Optional[int]) -> List[int]:
    """Collective bytes each epoch moves: ``exchange_bytes`` on exchange
    epochs and exactly 0 in between. Summing and dividing by ``epochs``
    gives the amortized bytes/epoch the PipelineReport records; the list is
    monotone non-increasing in ``period`` element-wise summed (pinned by a
    hypothesis sweep in tests/test_stale_mode.py)."""
    on = set(stale_exchange_epochs(epochs, period))
    return [int(exchange_bytes) if e in on else 0 for e in range(epochs)]


def _stale_cache_shapes(cfg: GNNConfig, n_pad: int) -> List[Tuple[int, int]]:
    """Per-layer cache shapes: the layer-i *input* activations [N_pad, F_i]."""
    dims = [cfg.feature_dim] + [cfg.hidden_dim] * (cfg.num_layers - 1)
    return [(n_pad, d) for d in dims]


def make_stale_train_steps(cfg: GNNConfig, halo: HaloExchangeSpec,
                           multilabel: bool, mesh: Mesh, lr: float = 1e-2
                           ) -> Dict[str, Callable]:
    """The three shard_map train steps of stale mode, keyed by discipline:

    - ``"exchange"``: ``(params, opt, t, keys) -> (params, opt, loss,
      caches)`` — identical math (and identical collectives) to the sync
      step, plus the per-layer post-refresh activation snapshots.
    - ``"stale"``: ``(params, opt, t, keys, caches) -> (params, opt, loss)``
      — halo rows read the frozen snapshots; lowers to ZERO collectives.
    - ``"frozen"``: ``(params, opt, t, keys) -> (params, opt, loss)`` — no
      halo refresh at all; used before the first exchange (period=∞), where
      it matches the local vmap step partition-for-partition.
    """
    from jax.experimental.shard_map import shard_map
    forward = make_halo_forward(cfg, halo)

    def loss_of(refresh_mode):
        def loss_fn(params, t, my_idx, dropout_key, caches):
            _, logits, new_caches = forward(params, t, my_idx, dropout_key,
                                            caches, refresh_mode)
            if multilabel:
                loss = sigmoid_bce(logits, t["labels"], t["train_mask"])
            else:
                loss = softmax_xent(logits, t["labels"], t["train_mask"])
            return loss, new_caches
        return loss_fn

    def local_step_of(refresh_mode):
        loss_fn = loss_of(refresh_mode)

        def local_step(params, opt, t, keys, *maybe_caches):
            # leading axis is the local shard of k: size 1 per device
            params1 = jax.tree.map(lambda x: x[0], params)
            opt1 = jax.tree.map(lambda x: x[0], opt)
            t1 = jax.tree.map(lambda x: x[0], t)
            caches1 = None
            if maybe_caches:
                caches1 = jax.tree.map(lambda x: x[0], maybe_caches[0])
            my_idx = jax.lax.axis_index("data")
            (loss, new_caches), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params1, t1, my_idx, keys[0], caches1)
            new_p, new_o = adamw_update(grads, opt1, params1, lr)
            expand = lambda x: x[None]
            outs = (jax.tree.map(expand, new_p), jax.tree.map(expand, new_o),
                    loss[None])
            if refresh_mode == "exchange":
                outs += (jax.tree.map(expand, new_caches),)
            return outs
        return local_step

    pspec = P("data")
    # check_rep=False: pallas_call (the use_kernel aggregation path) has no
    # shard_map replication rule (same rationale as make_sync_train_step)
    ex = shard_map(local_step_of("exchange"), mesh=mesh,
                   in_specs=(pspec, pspec, pspec, pspec),
                   out_specs=(pspec, pspec, pspec, pspec), check_rep=False)
    st = shard_map(local_step_of("cached"), mesh=mesh,
                   in_specs=(pspec, pspec, pspec, pspec, pspec),
                   out_specs=(pspec, pspec, pspec), check_rep=False)
    fz = shard_map(local_step_of("frozen"), mesh=mesh,
                   in_specs=(pspec, pspec, pspec, pspec),
                   out_specs=(pspec, pspec, pspec), check_rep=False)
    return {"exchange": jax.jit(ex), "stale": jax.jit(st),
            "frozen": jax.jit(fz)}


def train_stale(ds: NodeDataset, batch: PartitionBatch,
                halo: HaloExchangeSpec, cfg: GNNConfig, mesh: Mesh,
                epochs: int = 60, lr: float = 1e-2, seed: int = 0,
                sync_period: Optional[int] = 4,
                hlo_out: Optional[Dict[str, str]] = None,
                integrate: str = "none"
                ) -> Tuple[PyTree, np.ndarray]:
    """Periodic stale-synchronization training (DESIGN.md §12).

    Mirrors :func:`train_sync` (same mesh contract, same init/key schedule
    as BOTH other modes), but live halo exchange happens only at the epochs
    of :func:`stale_exchange_epochs`; other epochs train against the halo
    activations frozen at the last exchange. ``sync_period=1`` is the sync
    limit; ``sync_period in {0, None}`` never exchanges — the local limit.

    ``hlo_out`` receives ``"hlo"`` (the program that moves bytes: the
    exchange step, or the frozen step when no exchange ever happens) and
    ``"hlo_stale"`` (the between-exchange program — proven collective-free
    in tests). Returns (params, global_embeddings [n, E])."""
    from jax.experimental.shard_map import shard_map

    k = batch.k
    data_size = int(mesh.shape["data"])
    if data_size != k:
        raise ValueError(
            f"stale training needs one partition per device: mesh data axis "
            f"is {data_size} but k={k}. On CPU, relaunch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={k}.")
    pt = gather_partition_tensors(ds, batch)
    key = jax.random.PRNGKey(seed)
    params = init_partition_models(key, cfg, ds.num_classes, k)
    opt = jax.vmap(adamw_init)(params)
    tensors = {n: jnp.asarray(v) for n, v in _tensors_dict(pt).items()}

    schedule = set(stale_exchange_epochs(epochs, sync_period))
    n_exchange = len(schedule)
    has_stale_epochs = epochs > n_exchange
    steps = make_stale_train_steps(cfg, halo, ds.multilabel, mesh, lr)
    step_ex, step_st, step_fz = (steps["exchange"], steps["stale"],
                                 steps["frozen"])

    if hlo_out is not None:
        keys0 = jax.random.split(jax.random.fold_in(key, 0), k)
        if n_exchange:
            compiled_ex = step_ex.lower(params, opt, tensors,
                                        keys0).compile()
            hlo_out["hlo"] = compiled_ex.as_text()
            step_ex = compiled_ex
            if has_stale_epochs:
                caches0 = tuple(
                    jnp.zeros((k,) + s, jnp.float32)
                    for s in _stale_cache_shapes(cfg, batch.n_pad))
                compiled_st = step_st.lower(params, opt, tensors, keys0,
                                            caches0).compile()
                hlo_out["hlo_stale"] = compiled_st.as_text()
                step_st = compiled_st
        else:
            compiled_fz = step_fz.lower(params, opt, tensors,
                                        keys0).compile()
            # period=∞ never moves a byte: the frozen step is both the
            # "whole training" program and the between-exchange program
            hlo_out["hlo"] = compiled_fz.as_text()
            hlo_out["hlo_stale"] = compiled_fz.as_text()
            step_fz = compiled_fz

    epochs_ctr = obs.counter("train.epochs")
    exchanges_ctr = obs.counter("train.stale_exchanges")
    traced = obs.enabled()
    caches = None
    for e in range(epochs):
        keys = jax.random.split(jax.random.fold_in(key, e), k)
        kind = ("exchange" if e in schedule
                else "frozen" if caches is None else "stale")

        def run_epoch():
            nonlocal params, opt, caches
            if kind == "exchange":
                params, opt, loss, caches = step_ex(params, opt, tensors,
                                                    keys)
                exchanges_ctr.inc()
            elif kind == "frozen":
                params, opt, loss = step_fz(params, opt, tensors, keys)
            else:
                params, opt, loss = step_st(params, opt, tensors, keys,
                                            caches)
            return loss

        if traced:
            with obs.span("train.epoch", epoch=e, mode="stale",
                          kind=kind) as sp:
                _finish_epoch_span(sp, run_epoch())
        else:
            run_epoch()
        epochs_ctr.inc()

    # Embedding pass mirrors training: a live refresh when the run ever
    # exchanged (sync limit stays exact), the plain local forward otherwise
    # (local limit stays exact).
    forward = make_halo_forward(cfg, halo)
    eval_mode = "exchange" if n_exchange else "frozen"

    def eval_one(p, t):
        p1 = jax.tree.map(lambda x: x[0], p)
        t1 = jax.tree.map(lambda x: x[0], t)
        emb, _, _ = forward(p1, t1, jax.lax.axis_index("data"),
                            refresh_mode=eval_mode)
        return emb[None]

    pspec = P("data")
    emb_fn = jax.jit(shard_map(eval_one, mesh=mesh, in_specs=(pspec, pspec),
                               out_specs=pspec, check_rep=False))
    params, emb = apply_integration(
        params, integrate, lambda p: emb_fn(p, tensors), k)
    return params, pool_embeddings(np.asarray(emb), pt, ds.graph.n,
                                   cfg.embed_dim)


# ---------------------------------------------------------------------------
# Classifier on pooled embeddings (paper §5.2) + metrics
# ---------------------------------------------------------------------------
def train_classifier(ds: NodeDataset, embeddings: np.ndarray,
                     hidden: int = 256, epochs: int = 150, lr: float = 1e-2,
                     seed: int = 0, return_params: bool = False):
    """Train the MLP on frozen pooled embeddings; report accuracy/ROC-AUC.

    With ``return_params=True`` returns ``(metrics, params)`` — the trained
    MLP pytree the serving bundle exports so online answers reproduce the
    offline evaluation exactly (DESIGN.md §13)."""
    key = jax.random.PRNGKey(seed)
    params = init_mlp(key, embeddings.shape[1], hidden, ds.num_classes)
    opt = adamw_init(params)
    x = jnp.asarray(embeddings)
    y = jnp.asarray(ds.labels if ds.multilabel else ds.labels.astype(np.int32))
    tr = jnp.asarray(ds.train_mask.astype(np.float32))

    def loss_fn(p):
        logits = mlp_forward(p, x)
        if ds.multilabel:
            return sigmoid_bce(logits, y, tr)
        return softmax_xent(logits, y, tr)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(loss_fn)(p)
        p, o = adamw_update(g, o, p, lr)
        return p, o, loss

    for _ in range(epochs):
        params, opt, loss = step(params, opt)

    logits = np.asarray(jax.jit(mlp_forward)(params, x))
    out = {}
    for split, mask in (("train", ds.train_mask), ("val", ds.val_mask),
                        ("test", ds.test_mask)):
        if ds.multilabel:
            out[split] = float(mean_rocauc(ds.labels[mask], logits[mask]))
        else:
            pred = logits[mask].argmax(-1)
            out[split] = float((pred == ds.labels[mask]).mean())
    if return_params:
        return out, params
    return out


def mean_rocauc(y: np.ndarray, score: np.ndarray) -> float:
    """Mean ROC-AUC over tasks (rank statistic, ties averaged)."""
    aucs = []
    for t in range(y.shape[1]):
        yt, st = y[:, t], score[:, t]
        pos = yt > 0.5
        n_pos, n_neg = int(pos.sum()), int((~pos).sum())
        if n_pos == 0 or n_neg == 0:
            continue
        order = np.argsort(st, kind="mergesort")
        ranks = np.empty_like(order, dtype=np.float64)
        ranks[order] = np.arange(1, len(st) + 1)
        # average ties
        sorted_s = st[order]
        i = 0
        while i < len(st):
            j = i
            while j + 1 < len(st) and sorted_s[j + 1] == sorted_s[i]:
                j += 1
            if j > i:
                ranks[order[i:j + 1]] = (i + j + 2) / 2.0
            i = j + 1
        auc = (ranks[pos].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg)
        aucs.append(auc)
    return float(np.mean(aucs)) if aucs else 0.5
