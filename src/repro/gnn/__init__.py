"""GNN substrate: GCN/GraphSAGE models + the paper's training pipeline."""
from .model import (GNNConfig, gnn_forward, head_logits, init_gnn, init_mlp,
                    mlp_forward)
from .train import (PartitionTensors, apply_integration,
                    gather_partition_tensors,
                    init_partition_models, make_halo_forward,
                    make_local_train_step, make_stale_train_steps,
                    make_sync_train_step, make_sync_forward,
                    stale_bytes_per_epoch, stale_exchange_epochs,
                    train_local, train_stale,
                    train_sync, train_classifier, compute_embeddings,
                    pool_embeddings, mean_rocauc)

__all__ = ["GNNConfig", "gnn_forward", "head_logits", "init_gnn", "init_mlp",
           "mlp_forward",
           "PartitionTensors", "apply_integration",
           "gather_partition_tensors",
           "init_partition_models", "make_halo_forward",
           "make_local_train_step", "make_stale_train_steps",
           "make_sync_train_step", "make_sync_forward",
           "stale_bytes_per_epoch", "stale_exchange_epochs", "train_local",
           "train_stale", "train_sync", "train_classifier",
           "compute_embeddings", "pool_embeddings", "mean_rocauc"]
