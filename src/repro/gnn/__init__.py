"""GNN substrate: GCN/GraphSAGE models + the paper's training pipeline."""
from .model import GNNConfig, gnn_forward, init_gnn, init_mlp, mlp_forward
from .train import (PartitionTensors, gather_partition_tensors,
                    init_partition_models, make_local_train_step,
                    make_sync_train_step, make_sync_forward, train_local,
                    train_sync, train_classifier, compute_embeddings,
                    pool_embeddings, mean_rocauc)

__all__ = ["GNNConfig", "gnn_forward", "init_gnn", "init_mlp", "mlp_forward",
           "PartitionTensors", "gather_partition_tensors",
           "init_partition_models", "make_local_train_step",
           "make_sync_train_step", "make_sync_forward", "train_local",
           "train_sync", "train_classifier", "compute_embeddings",
           "pool_embeddings", "mean_rocauc"]
