"""GCN and GraphSAGE layers on padded edge-list subgraphs.

Aggregation is gather + segment-sum over the destination-sorted arc list of a
:class:`repro.core.assemble.PartitionBatch` row — exactly the access pattern
the Pallas kernel in :mod:`repro.kernels.csr_aggregate` implements for TPU;
here we default to the jnp path and switch to the kernel via ``use_kernel``.

Under ``use_kernel=True`` the layer entry points resolve a
:class:`repro.kernels.autotune.KernelConfig` for the call's shape (backend +
shape-bucket, DESIGN.md §14) and route the WHOLE layer through
:func:`repro.kernels.ops.fused_gcn_layer` — on TPU that is the fused
aggregate+dense+bias+relu kernel; on interpret-mode backends the autotuner
resolves to the XLA strategy of the same math. Resolution happens at trace
time and the config is a static jit argument, so retuning triggers a
recompile instead of serving a stale kernel.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp


def _kernel_config(n: int, e: int, f: int):
    from repro.kernels.autotune import get_config
    return get_config(n, e, f)


def aggregate_mean(h: jnp.ndarray, edge_src: jnp.ndarray,
                   edge_dst: jnp.ndarray, edge_weight: jnp.ndarray,
                   in_degree: jnp.ndarray, use_kernel: bool = False
                   ) -> jnp.ndarray:
    """Weighted mean over in-neighbors.  h: [N, F] -> [N, F].

    Padding arcs carry weight 0 and may point at any in-range row (the
    single contract — see :mod:`repro.kernels.ops`): the zero weight is
    what makes them no-ops on both paths. Both paths are differentiable
    w.r.t. ``h`` and ``edge_weight``; the kernel path fuses the degree
    normalization into the Pallas epilogue, so it is one kernel call.

    With ``use_kernel=True`` the autotuned config decides: the Pallas
    strategies run the tuned-tile aggregation kernel; the ``"xla"``
    strategy (interpret-mode backends) falls through to the jnp path —
    same math, no emulator.
    """
    if use_kernel:
        cfg = _kernel_config(h.shape[0], edge_src.shape[0], h.shape[1])
        if cfg.uses_pallas:
            from repro.kernels.ops import csr_aggregate
            inv = 1.0 / jnp.maximum(in_degree, 1.0)
            return csr_aggregate(h, edge_src, edge_dst, edge_weight,
                                 num_nodes=h.shape[0], inv_scale=inv,
                                 config=cfg)
    msgs = h[edge_src] * edge_weight[:, None]
    summed = jax.ops.segment_sum(msgs, edge_dst, num_segments=h.shape[0])
    return summed / jnp.maximum(in_degree[:, None], 1.0)


def gcn_layer(params: Dict[str, jnp.ndarray], h: jnp.ndarray,
              edge_src, edge_dst, edge_weight, in_degree,
              activate: bool = True, use_kernel: bool = False) -> jnp.ndarray:
    """Paper eq. (1): h_v = sigma( mean_{u in N(v)} W h_u ).

    Transform-then-aggregate commuted to aggregate-then-transform (they are
    identical for a linear W and cheaper when F_in >= F_out). The kernel
    path runs the whole layer through the fused dispatcher (one pallas_call
    on TPU — aggregate, dense, bias, and relu never leave VMEM).
    """
    if use_kernel:
        from repro.kernels.ops import fused_gcn_layer
        cfg = _kernel_config(h.shape[0], edge_src.shape[0], h.shape[1])
        return fused_gcn_layer(h, edge_src, edge_dst, edge_weight, in_degree,
                               params["w"], params["b"], activate=activate,
                               config=cfg)
    agg = aggregate_mean(h, edge_src, edge_dst, edge_weight, in_degree,
                         use_kernel)
    out = agg @ params["w"] + params["b"]
    return jax.nn.relu(out) if activate else out


def sage_layer(params: Dict[str, jnp.ndarray], h: jnp.ndarray,
               edge_src, edge_dst, edge_weight, in_degree,
               activate: bool = True, use_kernel: bool = False) -> jnp.ndarray:
    """Paper eq. (2): h_v = sigma( W . concat(h_v, AGG(h_u)) ) with mean AGG.

    Implemented as h @ W_self + agg @ W_neigh (== concat form, fused). The
    kernel path computes the neighbor half via the fused dispatcher
    (activation deferred until after the self term joins)."""
    if use_kernel:
        from repro.kernels.ops import fused_gcn_layer
        cfg = _kernel_config(h.shape[0], edge_src.shape[0], h.shape[1])
        neigh = fused_gcn_layer(h, edge_src, edge_dst, edge_weight,
                                in_degree, params["w_neigh"],
                                jnp.zeros_like(params["b"]),
                                activate=False, config=cfg)
        out = h @ params["w_self"] + neigh + params["b"]
        return jax.nn.relu(out) if activate else out
    agg = aggregate_mean(h, edge_src, edge_dst, edge_weight, in_degree,
                         use_kernel)
    out = h @ params["w_self"] + agg @ params["w_neigh"] + params["b"]
    return jax.nn.relu(out) if activate else out


def init_gcn_layer(key, f_in: int, f_out: int) -> Dict[str, jnp.ndarray]:
    scale = jnp.sqrt(2.0 / f_in)
    return {"w": jax.random.normal(key, (f_in, f_out), jnp.float32) * scale,
            "b": jnp.zeros((f_out,), jnp.float32)}


def init_sage_layer(key, f_in: int, f_out: int) -> Dict[str, jnp.ndarray]:
    k1, k2 = jax.random.split(key)
    scale = jnp.sqrt(2.0 / f_in)
    return {"w_self": jax.random.normal(k1, (f_in, f_out), jnp.float32) * scale,
            "w_neigh": jax.random.normal(k2, (f_in, f_out), jnp.float32) * scale,
            "b": jnp.zeros((f_out,), jnp.float32)}
