"""nemotron-4-340b [dense] — GQA kv=8, squared-ReLU FFN.

96L d_model=18432, 96 heads (kv=8), d_ff=73728, vocab=256000.
[arXiv:2402.16819]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    ffn_activation="squared_relu",
    norm="layernorm",
    rope_theta=10_000.0,
)
