"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.

38L d_model=2048, 32 heads (kv=32) for the shared attn, d_ff=8192 (shared
block MLP), ssm_state=64, vocab=32000. The single shared attention+MLP block
is re-applied every 6th layer (weights shared). [arXiv:2411.15242]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    block_pattern=("mamba", "mamba", "mamba", "mamba", "mamba",
                   "shared_attn"),
    ssm_state_dim=64,
    scan_layers=False,
    chunk_size=128,
    long_context="native",
)
