"""qwen3-4b [dense] — qk_norm, GQA kv=8, explicit head_dim=128.

36L d_model=2560, 32 heads (kv=8), d_ff=9728, vocab=151936.
[hf:Qwen/Qwen3-8B family, 4B point]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    arch_type="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    ffn_activation="swiglu",
    rope_theta=1_000_000.0,
)
