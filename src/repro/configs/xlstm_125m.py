"""xlstm-125m [ssm] — alternating mLSTM + sLSTM blocks.

12L d_model=768, 4 heads, vocab=50304 (no separate FFN; projections live
inside the xLSTM blocks). [arXiv:2405.04517]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
    scan_layers=False,
    chunk_size=128,
    tie_embeddings=True,
    long_context="native",
)
