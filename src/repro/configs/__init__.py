"""Architecture registry: ``get_config(name)`` / ``--arch <id>``.

Each module defines CONFIG (the exact assigned full-size architecture, source
cited) — the reduced smoke variant comes from ``CONFIG.reduced()``.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "seamless_m4t_large_v2",
    "phi3_vision_4p2b",
    "qwen2_moe_a2p7b",
    "qwen15_4b",
    "glm4_9b",
    "nemotron4_340b",
    "xlstm_125m",
    "deepseek_v2_236b",
    "qwen3_4b",
    "zamba2_1p2b",
]

# dashed aliases matching the assignment table
ALIASES = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "qwen1.5-4b": "qwen15_4b",
    "glm4-9b": "glm4_9b",
    "nemotron-4-340b": "nemotron4_340b",
    "xlstm-125m": "xlstm_125m",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen3-4b": "qwen3_4b",
    "zamba2-1.2b": "zamba2_1p2b",
}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; available: "
                       f"{ARCH_IDS + sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
