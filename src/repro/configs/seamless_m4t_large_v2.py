"""seamless-m4t-large-v2 [audio] — enc-dec multimodal translation backbone.

24L decoder (+24L speech encoder) d_model=1024, 16 heads (kv=16), d_ff=8192,
vocab=256206. [arXiv:2308.11596] Frontend (mel + conformer feature extractor)
is a stub: input_specs provides precomputed frame embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    ffn_activation="gelu",
    ffn_bias=True,
    norm="layernorm",
    encoder_layers=24,
    enc_seq_divisor=8,
    frontend="audio",
    causal=True,
)
