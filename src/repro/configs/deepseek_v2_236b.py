"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.

60L d_model=5120, 128 heads, per-expert d_ff=1536, vocab=102400; first layer
dense (d_ff=12288). [arXiv:2405.04434]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,             # MLA: kv heads notional, cache is latent
    d_ff=12288,                   # dense layers (first_k_dense)
    vocab_size=102400,
    ffn_activation="swiglu",
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    first_k_dense=1,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    head_dim=192,                 # qk_nope + qk_rope
)
