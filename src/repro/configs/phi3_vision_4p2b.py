"""phi-3-vision-4.2b [vlm] — phi3-mini LM backbone + CLIP ViT-L/14 frontend.

32L d_model=3072, 32 heads (kv=32), d_ff=8192, vocab=32064.
[hf:microsoft/Phi-3-vision-128k-instruct] Vision encoder + projector are a
stub: input_specs provides projected patch embeddings (576 tokens/image)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    ffn_activation="swiglu",
    frontend="vision",
    num_patch_tokens=576,
)
