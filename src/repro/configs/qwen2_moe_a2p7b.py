"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4.

24L d_model=2048, 16 heads (kv=16), per-expert d_ff=1408, vocab=151936.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=151936,
    qkv_bias=True,
    ffn_activation="swiglu",
    num_experts=60,
    num_shared_experts=4,
    top_k=4,
    moe_d_ff=1408,
)
