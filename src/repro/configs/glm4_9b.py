"""glm4-9b [dense] — RoPE (half-dim partial rotary), GQA kv=2.

40L d_model=4096, 32 heads (kv=2), d_ff=13696, vocab=151552.
[hf:THUDM/glm-4-9b]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    arch_type="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    qkv_bias=True,                # glm4 uses qkv bias (add_qkv_bias)
    rope_fraction=0.5,
    ffn_activation="swiglu",
)
