"""Pallas TPU kernel: single-token GQA decode attention (flash-style).

Decode attends ONE query token against a long KV cache — the hot loop of the
``decode_32k`` / ``long_500k`` serving shapes. Memory-bound: the roofline is
set by streaming K/V once through VMEM; the kernel therefore tiles the cache
sequence dimension and keeps the online-softmax state (m, l, acc) in VMEM
scratch across sequence blocks.

Layout: one grid row per KV head (GQA groups share a cache head), sequence
blocked by ``SEQ_BLOCK``. q is pre-grouped to [Hkv, G, D]; each step does two
MXU matmuls: logits = q_g @ k_blk^T  [G, SB]  and  acc += p @ v_blk  [G, D].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SEQ_BLOCK = 512
LANES = 128


def _kernel(len_ref, q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref):
    sb = pl.program_id(1)
    num_sb = pl.num_programs(1)

    @pl.when(sb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                  # [G, D] (pre-scaled)
    k = k_ref[0]                                  # [SB, D]
    v = v_ref[0]                                  # [SB, D]
    length = len_ref[0]
    sblk = k.shape[0]
    pos = sb * sblk + jax.lax.broadcasted_iota(jnp.int32, (1, sblk), 1)
    valid = pos < length                          # [1, SB]

    logits = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # [G, SB]
    logits = jnp.where(valid, logits, -1e30)

    m_prev = m_ref[:, :1]                         # [G, 1]
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                   # [G, SB]
    p = jnp.where(valid, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)               # [G, 1]
    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(sb == num_sb - 1)
    def _finish():
        out_ref[0] = acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        length: jnp.ndarray, interpret: bool = True
                        ) -> jnp.ndarray:
    """q: [H, D]; k, v: [S, Hkv, D]; length: scalar. Returns [H, D] f32->q.dtype.

    Matches :func:`repro.kernels.ref.flash_decode_ref` (scale 1/sqrt(D))."""
    hq, d = q.shape
    s, hkv, _ = k.shape
    g = hq // hkv
    assert g * hkv == hq, (hq, hkv)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qg = (q.astype(jnp.float32) * scale).reshape(hkv, g, d)
    # pad seq to SEQ_BLOCK; padded positions are masked via `length`
    s_pad = ((s + SEQ_BLOCK - 1) // SEQ_BLOCK) * SEQ_BLOCK
    kt = jnp.pad(jnp.moveaxis(k, 1, 0), ((0, 0), (0, s_pad - s), (0, 0)))
    vt = jnp.pad(jnp.moveaxis(v, 1, 0), ((0, 0), (0, s_pad - s), (0, 0)))
    length = jnp.asarray(length, jnp.int32).reshape(1)

    grid = (hkv, s_pad // SEQ_BLOCK)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, d), lambda h, sb: (h, 0, 0)),
            pl.BlockSpec((1, SEQ_BLOCK, d), lambda h, sb: (h, sb, 0)),
            pl.BlockSpec((1, SEQ_BLOCK, d), lambda h, sb: (h, sb, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda h, sb: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hkv, g, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(length, qg, kt, vt)
    return out.reshape(hq, d).astype(q.dtype)
