"""Pallas TPU kernels for the compute hot-spots (validated on CPU in
interpret mode; see each module's docstring for the TPU blocking design)."""
from .ops import csr_aggregate, flash_decode
from .ref import csr_aggregate_ref, flash_decode_ref

__all__ = ["csr_aggregate", "flash_decode", "csr_aggregate_ref",
           "flash_decode_ref"]
