"""Pallas TPU kernels for the compute hot-spots (validated on CPU in
interpret mode; see each module's docstring for the TPU blocking design).

The tiling/strategy choice is autotuned per (backend, shape-bucket) — see
:mod:`repro.kernels.autotune` and DESIGN.md §14."""
from .autotune import KernelConfig, autotune, get_config
from .ops import csr_aggregate, flash_decode, fused_gcn_layer
from .ref import csr_aggregate_ref, flash_decode_ref

__all__ = ["csr_aggregate", "flash_decode", "fused_gcn_layer",
           "csr_aggregate_ref", "flash_decode_ref",
           "KernelConfig", "autotune", "get_config"]
