"""Jit'd public wrappers around the Pallas kernels (padding + dispatch).

On CPU (this container) the kernels execute in interpret mode; on TPU set
``interpret=False`` (the default flips automatically based on the backend).

**Padding contract** (the single contract for every aggregation path — the
jnp segment-sum in :mod:`repro.gnn.layers`, the oracle in
:mod:`repro.kernels.ref`, and the Pallas kernel): *padding arcs carry weight
0 and may point at any in-range row; zero weight is what makes them no-ops,
not where they park.* By convention :mod:`repro.core.assemble` parks its
padding arcs at row ``n_pad - 1`` (keeps ``edge_dst`` sorted), while the
alignment padding added here points at row 0 — both are no-ops on both
paths, which ``tests/test_kernels.py`` pins.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .csr_aggregate import (EDGE_BLOCK, FEAT_TILE, NODE_TILE,
                            csr_aggregate_pallas)
from .flash_decode import flash_decode_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, mult: int, axis: int, value=0) -> jnp.ndarray:
    size = x.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(jax.jit, static_argnames=("num_nodes", "interpret"))
def csr_aggregate(h: jnp.ndarray, edge_src: jnp.ndarray,
                  edge_dst: jnp.ndarray, edge_weight: jnp.ndarray,
                  num_nodes: int, interpret: bool | None = None,
                  inv_scale: jnp.ndarray | None = None) -> jnp.ndarray:
    """Weighted neighbor-sum via the Pallas kernel, with automatic padding.

    Semantics match :func:`repro.kernels.ref.csr_aggregate_ref` exactly;
    with ``inv_scale`` given, each output row is additionally multiplied by
    it inside the kernel epilogue (pass ``1/max(in_degree, 1)`` to get the
    GCN weighted *mean* as one fused kernel call).

    Differentiable w.r.t. ``h`` and ``edge_weight``: the kernel carries a
    custom VJP whose transpose pass runs the same kernel over the reversed
    arc list — the src-sorted permutation it needs is precomputed here (and
    dead-code-eliminated by XLA on non-differentiated calls). ``inv_scale``
    and the arc lists are graph structure: zero cotangent by design.
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, f = h.shape
    hp = _pad_to(_pad_to(h, FEAT_TILE, 1), 8, 0)
    if hp.shape[0] > NODE_TILE:
        hp = _pad_to(hp, NODE_TILE, 0)
    n_pad = hp.shape[0]
    # alignment padding arcs carry weight 0 and park at row 0 — a no-op on
    # every path per the module-level padding contract
    es = _pad_to(edge_src, EDGE_BLOCK, 0)
    ed = _pad_to(edge_dst, EDGE_BLOCK, 0)
    ew = _pad_to(edge_weight, EDGE_BLOCK, 0)
    inv = None
    if inv_scale is not None:
        inv = jnp.pad(inv_scale.astype(jnp.float32), (0, n_pad - n),
                      constant_values=1.0)
    perm = jnp.argsort(es)           # bwd-only; DCE'd on forward-only calls
    out = csr_aggregate_pallas(hp, es, ed, ew, num_nodes=n_pad,
                               interpret=interpret, inv_scale=inv,
                               src_perm=perm)
    return out[:n, :f].astype(h.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 length: jnp.ndarray, interpret: bool | None = None
                 ) -> jnp.ndarray:
    """Single-token GQA decode attention. q: [H, D]; k/v: [S, Hkv, D]."""
    if interpret is None:
        interpret = not _on_tpu()
    return flash_decode_pallas(q, k, v, length, interpret=interpret)
