"""Jit'd public wrappers around the Pallas kernels (padding + dispatch).

On CPU (this container) the kernels execute in interpret mode; on TPU set
``interpret=False`` (the default flips automatically based on the backend).

**Padding contract** (the single contract for every aggregation path — the
jnp segment-sum in :mod:`repro.gnn.layers`, the oracle in
:mod:`repro.kernels.ref`, and the Pallas kernels): *padding arcs carry
weight 0 and may point at any in-range row; zero weight is what makes them
no-ops, not where they park.* By convention :mod:`repro.core.assemble`
parks its padding arcs at row ``n_pad - 1`` (keeps ``edge_dst`` sorted),
while the alignment padding added here points at row 0 — both are no-ops on
both paths, which ``tests/test_kernels.py`` pins.

**Strategy dispatch** (DESIGN.md §14): the tiling/strategy choice lives in
a :class:`repro.kernels.autotune.KernelConfig`, resolved per (backend,
shape-bucket) by :func:`repro.kernels.autotune.get_config` and threaded
through these wrappers as a *static* jit argument — never read from module
state inside a jit, so a cache update can never serve a stale compile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .autotune import KernelConfig
from .csr_aggregate import (DEFAULT_CONFIG, EDGE_BLOCK, FEAT_TILE, NODE_TILE,
                            csr_aggregate_pallas)
from .flash_decode import flash_decode_pallas
from .fused_layer import LANES, fused_gcn_pallas, fused_gcn_reference


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, mult: int, axis: int, value=0) -> jnp.ndarray:
    size = x.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def _pad_graph(h, edge_src, edge_dst, edge_weight, inv_scale,
               config: KernelConfig):
    """Pad (h, arcs, inv) to the config's tile contract. Alignment arcs
    carry weight 0 and park at row 0 — a no-op per the padding contract."""
    n = h.shape[0]
    hp = _pad_to(_pad_to(h, config.feat_tile, 1), 8, 0)
    if hp.shape[0] > config.node_tile:
        hp = _pad_to(hp, config.node_tile, 0)
    n_pad = hp.shape[0]
    granule = config.edge_granule
    es = _pad_to(edge_src, granule, 0)
    ed = _pad_to(edge_dst, granule, 0)
    ew = _pad_to(edge_weight, granule, 0)
    inv = None
    if inv_scale is not None:
        inv = jnp.pad(inv_scale.astype(jnp.float32), (0, n_pad - n),
                      constant_values=1.0)
    return hp, es, ed, ew, inv, n_pad


@functools.partial(jax.jit,
                   static_argnames=("num_nodes", "interpret", "config"))
def csr_aggregate(h: jnp.ndarray, edge_src: jnp.ndarray,
                  edge_dst: jnp.ndarray, edge_weight: jnp.ndarray,
                  num_nodes: int, interpret: bool | None = None,
                  inv_scale: jnp.ndarray | None = None,
                  config: KernelConfig | None = None) -> jnp.ndarray:
    """Weighted neighbor-sum via the Pallas kernel, with automatic padding.

    Semantics match :func:`repro.kernels.ref.csr_aggregate_ref` exactly;
    with ``inv_scale`` given, each output row is additionally multiplied by
    it inside the kernel epilogue (pass ``1/max(in_degree, 1)`` to get the
    GCN weighted *mean* as one fused kernel call).

    Differentiable w.r.t. ``h`` and ``edge_weight``: the kernel carries a
    custom VJP whose transpose pass runs the same kernel over the reversed
    arc list — the src-sorted permutation it needs is precomputed here (and
    dead-code-eliminated by XLA on non-differentiated calls). ``inv_scale``
    and the arc lists are graph structure: zero cotangent by design.

    ``config`` picks the tuned tile sizes/stream factor (default: the fixed
    PR 4 point); its *strategy* field is ignored here — this wrapper is
    always the Pallas aggregation (strategy dispatch happens one level up,
    in :func:`repro.gnn.layers.aggregate_mean` / :func:`fused_gcn_layer`).
    """
    if interpret is None:
        interpret = not _on_tpu()
    if config is None:
        config = DEFAULT_CONFIG
    n, f = h.shape
    hp, es, ed, ew, inv, n_pad = _pad_graph(
        h, edge_src, edge_dst, edge_weight, inv_scale, config)
    perm = jnp.argsort(es)           # bwd-only; DCE'd on forward-only calls
    out = csr_aggregate_pallas(hp, es, ed, ew, num_nodes=n_pad,
                               interpret=interpret, inv_scale=inv,
                               src_perm=perm, config=config)
    return out[:n, :f].astype(h.dtype)


@functools.partial(jax.jit, static_argnames=("activate", "interpret",
                                             "config"))
def fused_gcn_layer(h: jnp.ndarray, edge_src: jnp.ndarray,
                    edge_dst: jnp.ndarray, edge_weight: jnp.ndarray,
                    in_degree: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                    activate: bool = True, interpret: bool | None = None,
                    config: KernelConfig | None = None) -> jnp.ndarray:
    """One fused GNN layer: ``act(mean-aggregate(h) @ w + b)``.

    THE kernel-path entry point for the training modes (DESIGN.md §14):
    dispatches on ``config.strategy`` —

    - ``"pallas_fused"``: one ``pallas_call`` for the whole layer
      (:func:`repro.kernels.fused_layer.fused_gcn_pallas`), padding
      handled here;
    - ``"pallas"``: the PR 4 aggregation kernel with tuned tiles + an XLA
      dense epilogue;
    - ``"xla"``: the jnp composition under this jit (the right answer
      wherever Pallas would run in interpret mode).

    Differentiable w.r.t. ``h``, ``edge_weight``, ``w``, ``b`` on every
    strategy; parity across strategies is pinned in
    ``tests/test_fused_layer.py``.
    """
    if interpret is None:
        interpret = not _on_tpu()
    if config is None:
        config = DEFAULT_CONFIG
    inv = 1.0 / jnp.maximum(in_degree.astype(jnp.float32), 1.0)
    if config.strategy == "xla":
        return fused_gcn_reference(h, edge_src, edge_dst, edge_weight, inv,
                                   w, b, activate=activate)
    if config.strategy == "pallas":
        agg = csr_aggregate(h, edge_src, edge_dst, edge_weight,
                            num_nodes=h.shape[0], interpret=interpret,
                            inv_scale=inv, config=config)
        z = (agg.astype(jnp.float32) @ w.astype(jnp.float32)
             + b.astype(jnp.float32)[None, :])
        # jax.nn.relu for the gradient-at-zero convention (see fused_layer)
        out = jax.nn.relu(z) if activate else z
        return out.astype(h.dtype)
    # pallas_fused: pad to the full contract (incl. FO lanes), one call.
    n, f = h.shape
    fo = w.shape[1]
    hp, es, ed, ew, invp, n_pad = _pad_graph(
        h, edge_src, edge_dst, edge_weight, inv, config)
    wp = _pad_to(jnp.pad(w, ((0, hp.shape[1] - f), (0, 0))), LANES, 1)
    bp = _pad_to(b, LANES, 0)
    perm = jnp.argsort(es)
    out = fused_gcn_pallas(hp, es, ed, ew, num_nodes=n_pad, wmat=wp, b=bp,
                           activate=activate, interpret=interpret,
                           inv_scale=invp, src_perm=perm, config=config)
    return out[:n, :fo].astype(h.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 length: jnp.ndarray, interpret: bool | None = None
                 ) -> jnp.ndarray:
    """Single-token GQA decode attention. q: [H, D]; k/v: [S, Hkv, D]."""
    if interpret is None:
        interpret = not _on_tpu()
    return flash_decode_pallas(q, k, v, length, interpret=interpret)
