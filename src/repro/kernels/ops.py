"""Jit'd public wrappers around the Pallas kernels (padding + dispatch).

On CPU (this container) the kernels execute in interpret mode; on TPU set
``interpret=False`` (the default flips automatically based on the backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .csr_aggregate import (EDGE_BLOCK, FEAT_TILE, csr_aggregate_pallas)
from .flash_decode import flash_decode_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, mult: int, axis: int, value=0) -> jnp.ndarray:
    size = x.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


@functools.partial(jax.jit, static_argnames=("num_nodes", "interpret"))
def csr_aggregate(h: jnp.ndarray, edge_src: jnp.ndarray,
                  edge_dst: jnp.ndarray, edge_weight: jnp.ndarray,
                  num_nodes: int, interpret: bool | None = None
                  ) -> jnp.ndarray:
    """Weighted neighbor-sum via the Pallas kernel, with automatic padding.

    Semantics match :func:`repro.kernels.ref.csr_aggregate_ref` exactly.
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, f = h.shape
    hp = _pad_to(_pad_to(h, FEAT_TILE, 1), 8, 0)
    # padding edges carry weight 0 and may point at row 0 safely
    es = _pad_to(edge_src, EDGE_BLOCK, 0)
    ed = _pad_to(edge_dst, EDGE_BLOCK, 0)
    ew = _pad_to(edge_weight, EDGE_BLOCK, 0)
    out = csr_aggregate_pallas(hp, es, ed, ew, num_nodes=hp.shape[0],
                               interpret=interpret)
    return out[:n, :f].astype(h.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 length: jnp.ndarray, interpret: bool | None = None
                 ) -> jnp.ndarray:
    """Single-token GQA decode attention. q: [H, D]; k/v: [S, Hkv, D]."""
    if interpret is None:
        interpret = not _on_tpu()
    return flash_decode_pallas(q, k, v, length, interpret=interpret)
