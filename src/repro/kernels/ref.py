"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def csr_aggregate_ref(h: jnp.ndarray, edge_src: jnp.ndarray,
                      edge_dst: jnp.ndarray, edge_weight: jnp.ndarray,
                      num_nodes: int) -> jnp.ndarray:
    """Weighted neighbor-sum: out[d] = sum_{e: dst[e]=d} w[e] * h[src[e]].

    Padding arcs must carry weight 0 (they may point anywhere)."""
    msgs = h[edge_src].astype(jnp.float32) * edge_weight[:, None].astype(
        jnp.float32)
    out = jax.ops.segment_sum(msgs, edge_dst, num_segments=num_nodes)
    return out.astype(h.dtype)   # f32 accumulation, like the kernel


def flash_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     length: jnp.ndarray) -> jnp.ndarray:
    """Single-token decode attention oracle.

    q: [H, D]; k, v: [S, Hkv, D]; length: scalar valid prefix length.
    Grouped-query: H heads read kv head h // (H // Hkv). Returns [H, D]."""
    s, hkv, d = k.shape
    hq = q.shape[0]
    group = hq // hkv
    kk = jnp.repeat(k, group, axis=1)        # [S, H, D]
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("hd,shd->hs", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    mask = (jnp.arange(s) < length)[None, :]
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hs,shd->hd", p, vv.astype(jnp.float32)).astype(q.dtype)
