"""Pallas TPU kernel: one fused GNN layer — aggregate + dense + bias + relu
in a single ``pallas_call``, with a custom VJP so it is a real training path.

Why fuse (DESIGN.md §14): the PR 4 kernel computes the aggregate, writes it
to HBM, and XLA then reads it back for the dense transform — one full
[N, F] round trip plus a second kernel launch per layer. This kernel keeps
the aggregate tile in VMEM and runs the dense epilogue on it while it is
still resident, following the fused-epilogue idiom of
``kernels/flash_decode.py`` (accumulator scratch + ``pl.when`` init/finish
on the streaming grid dimension):

    grid = (node tiles i, feature tiles ft, edge granules sb); sb fastest
    per (i, ft):   agg[i, ft] = Σ_sb onehot-matmul(edge granule sb)
    at last sb:    agg[i, ft] *= inv[i]                  # mean epilogue
                   zacc[i]   += agg[i, ft] @ W[ft, :]    # dense, FT-chunked
    at last (ft):  out[i] = relu(zacc[i] + b)            # bias + act

``zacc`` ([NT, FO] f32 scratch) persists across grid steps (Pallas scratch
semantics), so the dense transform is accumulated feature-tile by
feature-tile without the aggregate ever leaving VMEM. The aggregate is
*also* written out — the backward pass needs it for dW, and XLA
dead-code-eliminates the store on forward-only calls. The edge streaming
and the degenerate-tile skip are shared with
:mod:`repro.kernels.csr_aggregate` (same SMEM lo/hi fast path).

Backward: with A the weighted adjacency, ``agg = diag(inv)·A·h``,
``z = agg@W + b``, ``out = act(z)``:

    gz  = g ⊙ 1[out > 0]          (relu; identity otherwise)
    db  = Σ_rows gz
    dW  = aggᵀ @ gz               (XLA matmul over the saved aggregate)
    da  = gz @ Wᵀ
    dh  = Aᵀ·diag(inv)·da         — the transpose-aggregation kernel
    dw[e] = inv[dst[e]]·<da[dst[e]], h[src[e]]>  — the edge-dot kernel

i.e. the reverse pass reuses the PR 4 kernels (`_aggregate`, `_edge_dot`)
with the same KernelConfig, so tuned tiles apply to both directions.

:func:`fused_gcn_reference` is the jnp composition of the same math — the
parity oracle in tests AND the ``"xla"`` strategy the autotuner picks on
backends where Pallas would run in interpret mode.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .autotune import KernelConfig
from .csr_aggregate import (DEFAULT_CONFIG, ShapeContractError, _aggregate,
                            _edge_dot, _node_tile, check_shape_contract,
                            edge_block_ranges)

LANES = 128


def fused_gcn_reference(h, edge_src, edge_dst, edge_weight, inv_scale,
                        w, b, activate: bool = True) -> jnp.ndarray:
    """jnp composition of the fused layer: oracle + the "xla" strategy."""
    n = h.shape[0]
    msgs = (jnp.take(h, edge_src, axis=0).astype(jnp.float32)
            * edge_weight.astype(jnp.float32)[:, None])
    agg = jax.ops.segment_sum(msgs, edge_dst, num_segments=n)
    agg = agg * inv_scale.astype(jnp.float32)[:, None]
    z = agg @ w.astype(jnp.float32) + b.astype(jnp.float32)[None, :]
    # jax.nn.relu, NOT jnp.maximum: their values agree but their gradients
    # at z == 0 differ (relu' = 0 vs maximum's 0.5 tie split) — and z == 0
    # is exact for zero-degree rows under zero-initialized biases. The
    # kernel VJP's (out > 0) mask follows the relu convention.
    out = jax.nn.relu(z) if activate else z
    return out.astype(h.dtype)


def _fused_kernel(lo_ref, hi_ref, src_ref, dst_ref, w_ref, inv_ref, h_ref,
                  wmat_ref, b_ref, agg_ref, out_ref, zacc_ref, *,
                  edge_block: int, stream: int, activate: bool):
    ftid = pl.program_id(1)
    sb = pl.program_id(2)
    num_ft = pl.num_programs(1)
    last_sb = sb == pl.num_programs(2) - 1

    @pl.when(sb == 0)
    def _init():
        agg_ref[...] = jnp.zeros_like(agg_ref)

    src_all = src_ref[...]
    dst_all = dst_ref[...]
    w_all = w_ref[...].astype(jnp.float32)
    h = h_ref[...]
    nt = agg_ref.shape[0]
    tile_lo = pl.program_id(0) * nt

    for s in range(stream):                  # unrolled streamed sub-blocks
        blk = sb * stream + s
        lo = lo_ref[blk]
        hi = hi_ref[blk]

        @pl.when(jnp.logical_and(hi >= tile_lo, lo < tile_lo + nt))
        def _compute(s=s):
            src = src_all[s * edge_block:(s + 1) * edge_block]
            dst = dst_all[s * edge_block:(s + 1) * edge_block]
            w = w_all[s * edge_block:(s + 1) * edge_block]
            gathered = jnp.take(h, src, axis=0).astype(jnp.float32)
            rows = (jax.lax.broadcasted_iota(jnp.int32, (nt, edge_block), 0)
                    + tile_lo)
            scatter = jnp.where(rows == dst[None, :], w[None, :], 0.0)
            agg_ref[...] += jax.lax.dot(scatter, gathered,
                                        preferred_element_type=jnp.float32)

    # fused epilogue: normalization, then the dense transform on the still-
    # resident aggregate tile (zacc accumulates over feature tiles), then
    # bias + activation once the last feature tile lands.
    @pl.when(last_sb)
    def _normalize():
        agg_ref[...] = (agg_ref[...]
                        * inv_ref[...].astype(jnp.float32)[:, None])

    @pl.when(jnp.logical_and(last_sb, ftid == 0))
    def _zacc_init():
        zacc_ref[...] = jnp.zeros_like(zacc_ref)

    @pl.when(last_sb)
    def _dense():
        zacc_ref[...] += jax.lax.dot(
            agg_ref[...], wmat_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(last_sb, ftid == num_ft - 1))
    def _finish():
        z = zacc_ref[...] + b_ref[...].astype(jnp.float32)[None, :]
        out_ref[...] = jnp.maximum(z, 0.0) if activate else z


def _fused_forward(h, edge_src, edge_dst, edge_weight, inv_scale, wmat, b,
                   *, activate: bool, interpret: bool, config: KernelConfig):
    """Aligned-domain fused layer: returns (out [N, FO], agg [N, F])."""
    n, f = h.shape
    e = edge_src.shape[0]
    fo = wmat.shape[1]
    nt = _node_tile(n, config.node_tile)
    eb, stream = config.edge_block, config.stream
    ft_sz = min(config.feat_tile, f)
    granule = eb * stream
    grid = (n // nt, f // ft_sz, e // granule)
    lo, hi = edge_block_ranges(edge_dst, eb)
    agg, out = pl.pallas_call(
        functools.partial(_fused_kernel, edge_block=eb, stream=stream,
                          activate=activate),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),    # lo
            pl.BlockSpec(memory_space=pltpu.SMEM),    # hi
            pl.BlockSpec((granule,), lambda i, ft, sb: (sb,)),
            pl.BlockSpec((granule,), lambda i, ft, sb: (sb,)),
            pl.BlockSpec((granule,), lambda i, ft, sb: (sb,)),
            pl.BlockSpec((nt,), lambda i, ft, sb: (i,)),
            pl.BlockSpec((n, ft_sz), lambda i, ft, sb: (0, ft)),
            pl.BlockSpec((ft_sz, fo), lambda i, ft, sb: (ft, 0)),
            pl.BlockSpec((fo,), lambda i, ft, sb: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((nt, ft_sz), lambda i, ft, sb: (i, ft)),
            pl.BlockSpec((nt, fo), lambda i, ft, sb: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, f), jnp.float32),
            jax.ShapeDtypeStruct((n, fo), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((nt, fo), jnp.float32)],
        interpret=interpret,
    )(lo, hi, edge_src, edge_dst, edge_weight, inv_scale, h, wmat, b)
    return out, agg


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _fused_diff(interpret, activate, config, h, edge_src, edge_dst,
                edge_weight, inv_scale, wmat, b, src_perm):
    del src_perm                     # bwd-only (see csr_aggregate)
    out, _ = _fused_forward(h, edge_src, edge_dst, edge_weight, inv_scale,
                            wmat, b, activate=activate, interpret=interpret,
                            config=config)
    return out


def _fused_diff_fwd(interpret, activate, config, h, edge_src, edge_dst,
                    edge_weight, inv_scale, wmat, b, src_perm):
    out, agg = _fused_forward(h, edge_src, edge_dst, edge_weight, inv_scale,
                              wmat, b, activate=activate,
                              interpret=interpret, config=config)
    return out, (h, edge_src, edge_dst, edge_weight, inv_scale, wmat,
                 src_perm, agg, out)


def _fused_diff_bwd(interpret, activate, config, res, g):
    h, src, dst, w, inv, wmat, perm, agg, out = res
    gz = g.astype(jnp.float32)
    if activate:
        gz = gz * (out > 0.0)
    db = jnp.sum(gz, axis=0)
    dwmat = agg.T @ gz                                   # [F, FO]
    da = gz @ wmat.astype(jnp.float32).T                 # [N, F]
    ones = jnp.ones((h.shape[0],), jnp.float32)
    # dh: transpose aggregation over the reversed src-sorted arc list,
    # normalization folded into the reverse weights (PR 4 kernel, same cfg).
    rev_w = jnp.take(w.astype(jnp.float32) * jnp.take(inv, dst), perm)
    dh = _aggregate(da, jnp.take(dst, perm), jnp.take(src, perm), rev_w,
                    ones, interpret=interpret, config=config).astype(h.dtype)
    da_scaled = da * inv.astype(jnp.float32)[:, None]
    dw = _edge_dot(jnp.take(h.astype(jnp.float32), src, axis=0),
                   jnp.take(da_scaled, dst, axis=0),
                   interpret=interpret, config=config).astype(w.dtype)
    zero_int = lambda x: np.zeros(x.shape, jax.dtypes.float0)
    return (dh, zero_int(src), zero_int(dst), dw, jnp.zeros_like(inv),
            dwmat.astype(wmat.dtype), db, zero_int(perm))


_fused_diff.defvjp(_fused_diff_fwd, _fused_diff_bwd)


@functools.partial(jax.jit, static_argnames=("num_nodes", "activate",
                                             "interpret", "config"))
def fused_gcn_pallas(h: jnp.ndarray, edge_src: jnp.ndarray,
                     edge_dst: jnp.ndarray, edge_weight: jnp.ndarray,
                     num_nodes: int, wmat: jnp.ndarray, b: jnp.ndarray,
                     activate: bool = True, interpret: bool = True,
                     inv_scale: jnp.ndarray | None = None,
                     src_perm: jnp.ndarray | None = None,
                     config: KernelConfig | None = None) -> jnp.ndarray:
    """Aligned-domain fused GNN layer (one pallas_call; see module doc).

    ``out = act((inv_scale ⊙ Σ_e w[e]·h[src[e]]→dst[e]) @ wmat + b)``.
    Differentiable w.r.t. ``h``, ``edge_weight``, ``wmat``, ``b``. Shape
    contract: the csr_aggregate contract plus FO % 128 == 0 (lane multiple
    of the resident output tile); :func:`repro.kernels.ops.fused_gcn_layer`
    applies the padding automatically.
    """
    if config is None:
        config = DEFAULT_CONFIG
    n, f = h.shape
    e = edge_src.shape[0]
    fo = wmat.shape[1]
    check_shape_contract(n, f, e, num_nodes, config)
    if fo % LANES != 0:
        raise ShapeContractError(
            [f"FO={fo} not a multiple of {LANES} (output lane tile)"],
            (n, f, e), (n, f, e))
    if inv_scale is None:
        inv_scale = jnp.ones((n,), jnp.float32)
    if src_perm is None:
        src_perm = jnp.argsort(edge_src)
    return _fused_diff(interpret, activate, config, h, edge_src, edge_dst,
                       edge_weight, inv_scale.astype(jnp.float32), wmat, b,
                       src_perm)
