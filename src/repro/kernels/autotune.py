"""Autotuned kernel configs: per-(backend, shape-bucket) tiling + strategy.

PR 4 hard-coded ``NODE_TILE=512 / EDGE_BLOCK=256 / FEAT_TILE=128`` — one
point in a search space whose optimum moves with the backend and the
partition shape. This module owns that choice (DESIGN.md §14):

* **KernelConfig** — the tunable contract: a *strategy* plus tile sizes.
  Strategies:

  - ``"pallas_fused"`` — the fused GNN-layer kernel (aggregate + dense +
    bias + relu in ONE ``pallas_call``, :mod:`repro.kernels.fused_layer`);
    the TPU default — it amortizes kernel-launch overhead and keeps the
    aggregate tile in VMEM through the dense epilogue.
  - ``"pallas"`` — the unfused PR 4 aggregation kernel with tuned tiles;
    the dense transform stays an XLA matmul.
  - ``"xla"`` — the same fused-layer math lowered directly through XLA
    (gather + segment-sum + dense epilogue under one jit). On backends
    where Pallas executes in *interpret mode* (CPU — a correctness
    emulator, not a perf path) this is the only sane choice: the one-hot
    scatter matmul costs O(N·E·F) dense FLOPs, which only an MXU makes
    affordable. Interpret-mode candidates are therefore never measured by
    default — they lose by ~15x before the tuner starts.

* **shape buckets** — configs are keyed by ``(backend, bucket)`` where the
  bucket rounds N and E up to powers of two and F up to the lane multiple,
  so one tuning run covers every partition that pads into the same bucket
  (the PR 2 fingerprint discipline applied to kernel shapes).

* **disk cache** — tuning is paid once: results land in a JSON cache
  (``REPRO_AUTOTUNE_CACHE`` or ``~/.cache/repro/autotune_cache.json``,
  atomic rewrite), consulted before the packaged factory table
  (``autotune_defaults.json``) and the per-backend fallback. A second
  process sees the first one's tuned configs — determinism across
  processes is pinned by ``tests/test_fused_layer.py``.

Resolution order for :func:`get_config`:
``override() > in-memory memo > user cache > factory defaults > fallback``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Tuple

from repro import obs

__all__ = [
    "KernelConfig", "ShapeBucket", "shape_bucket", "get_config", "autotune",
    "override", "candidate_space", "vmem_bytes", "cache_path",
    "clear_memory_cache", "VMEM_BUDGET",
]

# Pallas TPU VMEM working-set ceiling the candidate filter enforces
# (per-core VMEM is ~16 MB; leave headroom for the runtime).
VMEM_BUDGET = 14 * 1024 * 1024

STRATEGIES = ("pallas_fused", "pallas", "xla")


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One point in the kernel search space (hashable — usable as a jit
    static argument). Tile fields are meaningful for the pallas strategies;
    the ``xla`` strategy keeps them for bookkeeping only."""
    strategy: str = "pallas"
    node_tile: int = 512
    edge_block: int = 256
    feat_tile: int = 128
    stream: int = 2          # edge blocks streamed per grid step (the DMA
                             # granule is edge_block*stream; sub-blocks are
                             # skipped per-tile via the dst-range fast path)

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"strategy must be one of {STRATEGIES}, got {self.strategy!r}")

    @property
    def uses_pallas(self) -> bool:
        return self.strategy in ("pallas_fused", "pallas")

    @property
    def edge_granule(self) -> int:
        return self.edge_block * self.stream

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "KernelConfig":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)
                      if f.name in d})


@dataclasses.dataclass(frozen=True)
class ShapeBucket:
    """Power-of-two shape bucket a concrete (n, e, f) pads into."""
    n: int
    e: int
    f: int

    @property
    def key(self) -> str:
        return f"n{self.n}_e{self.e}_f{self.f}"


def _pow2_ceil(x: int) -> int:
    x = max(int(x), 1)
    return 1 << (x - 1).bit_length()


def shape_bucket(n: int, e: int, f: int) -> ShapeBucket:
    """Bucket: N and E to the next power of two (min 8 nodes / 128 edges),
    F to the next lane multiple (128)."""
    return ShapeBucket(n=max(_pow2_ceil(n), 8),
                       e=max(_pow2_ceil(e), 128),
                       f=((max(int(f), 1) + 127) // 128) * 128)


def vmem_bytes(bucket: ShapeBucket, cfg: KernelConfig,
               f_out: Optional[int] = None) -> int:
    """f32 VMEM working set of one fused-layer grid step (DESIGN.md §14):
    the full gather column, the streamed edge granule, the resident
    aggregate/output tiles, the weight block, and the dense accumulator."""
    fo = f_out if f_out is not None else bucket.f
    ft = min(cfg.feat_tile, bucket.f)
    nt = min(cfg.node_tile, bucket.n)
    gather_col = bucket.n * ft
    edges = 3 * cfg.edge_granule          # src, dst, w (int32/f32 alike)
    agg_tile = nt * ft
    w_block = ft * fo
    z_acc = nt * fo
    out_tile = nt * fo
    return 4 * (gather_col + edges + agg_tile + w_block + z_acc + out_tile)


# ---------------------------------------------------------------------------
# Cache: user file + packaged factory defaults + in-memory memo
# ---------------------------------------------------------------------------
_DEFAULTS_PATH = os.path.join(os.path.dirname(__file__),
                              "autotune_defaults.json")
_memo: Dict[Tuple[str, str], KernelConfig] = {}
_user_cache_loaded: Optional[str] = None   # path the memo was seeded from
_override_stack: List[KernelConfig] = []


def cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune_cache.json"))


def clear_memory_cache() -> None:
    """Drop the in-process memo (tests; forces a re-read of the files)."""
    global _user_cache_loaded
    _memo.clear()
    _user_cache_loaded = None


@contextlib.contextmanager
def override(config: KernelConfig):
    """Force every resolution to ``config`` inside the context (tests, and
    the roofline benchmark's forced-strategy rows)."""
    _override_stack.append(config)
    try:
        yield config
    finally:
        _override_stack.pop()


def _read_json(path: str) -> Dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _configs_from_file(path: str) -> Dict[Tuple[str, str], KernelConfig]:
    out = {}
    for backend, buckets in _read_json(path).get("configs", {}).items():
        for key, entry in buckets.items():
            try:
                out[(backend, key)] = KernelConfig.from_dict(entry["config"])
            except (KeyError, TypeError, ValueError):
                continue
    return out


def _seed_memo() -> None:
    """Load factory defaults then the user cache (user wins) into the memo."""
    global _user_cache_loaded
    path = cache_path()
    if _user_cache_loaded == path:
        return
    fresh = {}
    fresh.update(_configs_from_file(_DEFAULTS_PATH))
    fresh.update(_configs_from_file(path))
    _memo.clear()
    _memo.update(fresh)
    _user_cache_loaded = path


def _persist(backend: str, bucket: ShapeBucket, config: KernelConfig,
             measurements: Dict[str, float]) -> None:
    path = cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    data = _read_json(path)
    data.setdefault("version", 1)
    entry = {
        "config": config.as_dict(),
        "source": "tuned",
        "measured_ms": {k: round(v, 4) for k, v in measurements.items()},
        "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    data.setdefault("configs", {}).setdefault(backend, {})[bucket.key] = entry
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    os.replace(tmp, path)


def _backend() -> str:
    import jax
    return jax.default_backend()


def fallback_config(backend: Optional[str] = None) -> KernelConfig:
    """Untuned default: the fused Pallas kernel on TPU (PR 4's tile point),
    the XLA lowering everywhere Pallas would run in interpret mode."""
    backend = backend or _backend()
    if backend == "tpu":
        return KernelConfig(strategy="pallas_fused")
    return KernelConfig(strategy="xla")


def get_config(n: int, e: int, f: int,
               backend: Optional[str] = None) -> KernelConfig:
    """Resolve the kernel config for a concrete shape (trace-time python:
    cheap dict lookups; the result is passed into jits as a static arg)."""
    if _override_stack:
        return _override_stack[-1]
    backend = backend or _backend()
    _seed_memo()
    bucket = shape_bucket(n, e, f)
    hit = _memo.get((backend, bucket.key))
    if hit is not None:
        return hit
    return fallback_config(backend)


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------
def candidate_space(bucket: ShapeBucket,
                    backend: Optional[str] = None) -> List[KernelConfig]:
    """Deterministically-ordered candidates for one (backend, bucket).

    TPU: the pallas strategies over a tile sweep, VMEM-filtered. Other
    backends: the XLA strategy, plus the interpret-mode pallas points only
    when ``REPRO_AUTOTUNE_EXHAUSTIVE=1`` (they are emulation, ~15x off —
    measuring them by default just burns CI minutes)."""
    backend = backend or _backend()
    if backend != "tpu":
        cands = [KernelConfig(strategy="xla")]
        if os.environ.get("REPRO_AUTOTUNE_EXHAUSTIVE") == "1":
            cands += [KernelConfig(strategy="pallas_fused"),
                      KernelConfig(strategy="pallas")]
        return cands
    cands = []
    for strategy in ("pallas_fused", "pallas"):
        for nt in (256, 512, 1024):
            if nt > bucket.n and nt != min(256, bucket.n):
                continue
            for eb in (256, 512, 1024):
                for ft in (128, 256):
                    if ft > bucket.f:
                        continue
                    for stream in (1, 2, 4):
                        cfg = KernelConfig(strategy=strategy, node_tile=nt,
                                           edge_block=eb, feat_tile=ft,
                                           stream=stream)
                        if cfg.edge_granule > bucket.e:
                            continue
                        if vmem_bytes(bucket, cfg) > VMEM_BUDGET:
                            continue
                        cands.append(cfg)
    if not cands:
        # past the gather-column VMEM cliff (N·FT alone exceeds the
        # budget, ~28k padded nodes — DESIGN.md §3/§14) no pallas point
        # fits; the honest answer is the XLA lowering.
        return [KernelConfig(strategy="xla")]
    return cands


def _measure(cfg: KernelConfig, bucket: ShapeBucket,
             repeats: int = 3) -> float:
    """Median wall ms of one fused-layer fwd+bwd at the bucket shape.

    The probe is the training hot path: ``value_and_grad`` w.r.t. (h, W, b)
    of a scalar loss over the fused GCN layer, jitted with ``cfg`` static.
    The first call (compile) is excluded; the median over ``repeats`` is
    returned."""
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import fused_gcn_layer

    rng = np.random.default_rng(0)
    n, e, f = bucket.n, bucket.e, bucket.f
    h = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(np.sort(rng.integers(0, n, e)), jnp.int32)
    w_edge = jnp.asarray(rng.random(e), jnp.float32)
    deg = jnp.asarray(np.bincount(np.asarray(dst), minlength=n)[:n],
                      jnp.float32)
    w = jnp.asarray(rng.normal(size=(f, f)) * 0.1, jnp.float32)
    b = jnp.zeros((f,), jnp.float32)

    def loss(h, w, b):
        out = fused_gcn_layer(h, src, dst, w_edge, deg, w, b,
                              activate=True, config=cfg)
        return (out * out).sum()

    step = jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))
    jax.block_until_ready(step(h, w, b))        # compile, excluded
    walls = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(step(h, w, b))
        walls.append((time.perf_counter() - t0) * 1e3)
    walls.sort()
    return walls[len(walls) // 2]


def autotune(n: int, e: int, f: int, backend: Optional[str] = None,
             force: bool = False, repeats: int = 3
             ) -> Tuple[KernelConfig, Dict[str, float]]:
    """Tune the (backend, bucket) of a concrete shape and cache the winner.

    Returns ``(config, measured_ms_per_candidate)``; a cache hit returns
    the cached config with an empty measurement table unless ``force``.
    Candidates are measured in deterministic order and the winner is the
    strict argmin (first wins ties), so re-tuning is reproducible up to
    measurement noise — and the disk cache makes every later process see
    the same choice without re-measuring."""
    backend = backend or _backend()
    bucket = shape_bucket(n, e, f)
    if not force:
        _seed_memo()
        hit = _memo.get((backend, bucket.key))
        if hit is not None:
            obs.counter("autotune.cache_hits").inc()
            return hit, {}
    cands = candidate_space(bucket, backend)
    measurements: Dict[str, float] = {}
    with obs.span("autotune.bucket", bucket=bucket.key, backend=backend,
                  candidates=len(cands)) as bsp:
        best, best_ms = cands[0], float("inf")
        if len(cands) == 1:
            best_ms = 0.0     # single candidate: nothing to measure
        else:
            for cfg in cands:
                with obs.span("autotune.candidate",
                              candidate=_cand_key(cfg)) as csp:
                    ms = _measure(cfg, bucket, repeats=repeats)
                    csp.set(measured_ms=round(ms, 4))
                obs.counter("autotune.candidates_measured").inc()
                measurements[_cand_key(cfg)] = ms
                if ms < best_ms:
                    best, best_ms = cfg, ms
        bsp.set(winner=_cand_key(best))
    _persist(backend, bucket, best, measurements)
    _memo[(backend, bucket.key)] = best
    return best, measurements


def _cand_key(cfg: KernelConfig) -> str:
    return (f"{cfg.strategy}/nt{cfg.node_tile}/eb{cfg.edge_block}/"
            f"ft{cfg.feat_tile}/s{cfg.stream}")
