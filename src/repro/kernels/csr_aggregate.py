"""Pallas TPU kernel: weighted neighbor aggregation (edge-list SpMM),
node-tiled and differentiable.

The GNN hot-spot: ``out[d] += w[e] * h[src[e]]`` over a weight-0-padded
arc list. GPU implementations use shared-memory atomics; TPU has no scatter
hardware, so we ADAPT (see DESIGN.md §3): the scatter becomes a **one-hot
matmul** that feeds the MXU —

    for each node tile N_t, feature tile F_t, edge block E_b:
        G   = h[src[E_b], F_t]                        # gather   [EB, FT]
        S   = onehot(dst[E_b] - N_t.start) * w[E_b]   # scatter  [NT, EB]
        out[N_t, F_t] += S @ G                        # MXU      [NT, FT]
    after the last edge block:
        out[N_t, F_t] *= inv_scale[N_t, None]         # fused epilogue

Blocking: the grid is (node tiles × feature tiles × edge blocks). Earlier
revisions kept the whole node dimension resident, which capped partitions at
~8k padded nodes; the node dimension is now tiled (``NODE_TILE`` rows of the
one-hot scatter matrix per step, rows outside the tile masked to zero), so
the VMEM working set per step is

    (N·FT + NT·EB + NT·FT + EB·FT) · 4 B

where only the gather operand ``h`` (one [N, FT] feature column) still
scales with N. With NT=512, FT=128, EB=256 and N=25 600 (PR 3's
``--dataset-scale`` partitions: 100k nodes / k=4, plus halo and padding)
that is 13.1 + 0.5 + 0.25 + 0.13 ≈ 14 MB — inside the ~16 MB VMEM budget;
the old layout needed N·EB = 25 MB for the scatter matrix alone. The output
block index is independent of the edge-block grid dimension, so Pallas keeps
it resident and we accumulate across edge blocks (init at block 0, scale
epilogue at the last block). Accumulation is f32. Beyond N ≈ 28k padded
nodes the gather operand itself would have to be streamed from HBM; the
paper's partitioning keeps partitions far smaller (k scales with the graph).

Differentiation (DESIGN.md §11): ``csr_aggregate_pallas`` carries a
``jax.custom_vjp``. With A the [N, N] weighted adjacency the forward is
``out = diag(inv_scale) · A · h``, so

* the h-cotangent is ``Aᵀ · diag(inv_scale) · g`` — the *same* kernel run
  over the reversed arc list ``(dst, src)`` with weights
  ``w[e]·inv_scale[dst[e]]`` and no epilogue, re-sorted by the new
  destination (= original source) via a precomputed permutation;
* the edge-weight cotangent is the per-edge row dot
  ``dw[e] = inv_scale[dst[e]] · <g[dst[e]], h[src[e]]>`` — a small
  companion kernel (``_edge_dot_kernel``) that fuses the multiply-reduce
  over feature tiles so the [E, F] products never hit HBM;
* ``inv_scale`` (the fused degree normalization) and the arc lists are
  graph *structure*, not trainable data: their cotangents are defined as
  zero (``float0`` for the int arrays).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NODE_TILE = 512
EDGE_BLOCK = 256
FEAT_TILE = 128


def _agg_kernel(src_ref, dst_ref, w_ref, inv_ref, h_ref, out_ref):
    eb = pl.program_id(2)

    @pl.when(eb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    src = src_ref[...]                       # [EB] int32
    dst = dst_ref[...]                       # [EB] int32
    w = w_ref[...].astype(jnp.float32)       # [EB]
    h = h_ref[...]                           # [N, FT] full gather column
    nt, ebs = out_ref.shape[0], src.shape[0]
    # gather source rows: [EB, FT]
    gathered = jnp.take(h, src, axis=0).astype(jnp.float32)
    # masked one-hot scatter for THIS node tile:
    # S[i, e] = w[e] * (dst[e] == tile_start + i)  -> [NT, EB]
    rows = (jax.lax.broadcasted_iota(jnp.int32, (nt, ebs), 0)
            + pl.program_id(0) * nt)
    scatter = jnp.where(rows == dst[None, :], w[None, :], 0.0)
    out_ref[...] += jax.lax.dot(scatter, gathered,
                                preferred_element_type=jnp.float32)

    @pl.when(eb == pl.num_programs(2) - 1)
    def _epilogue():
        out_ref[...] = out_ref[...] * inv_ref[...].astype(jnp.float32)[:, None]


def _edge_dot_kernel(a_ref, b_ref, out_ref):
    ft = pl.program_id(1)

    @pl.when(ft == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.sum(a_ref[...].astype(jnp.float32)
                            * b_ref[...].astype(jnp.float32), axis=1)


def _node_tile(n: int) -> int:
    return n if n <= NODE_TILE else NODE_TILE


def _aggregate(h, edge_src, edge_dst, edge_weight, inv_scale, *,
               interpret: bool) -> jnp.ndarray:
    """Aligned-domain forward: one pallas_call, f32 accumulate + epilogue."""
    n, f = h.shape
    e = edge_src.shape[0]
    nt = _node_tile(n)
    grid = (n // nt, f // FEAT_TILE, e // EDGE_BLOCK)
    out = pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((EDGE_BLOCK,), lambda i, ft, eb: (eb,)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i, ft, eb: (eb,)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i, ft, eb: (eb,)),
            pl.BlockSpec((nt,), lambda i, ft, eb: (i,)),
            pl.BlockSpec((n, FEAT_TILE), lambda i, ft, eb: (0, ft)),
        ],
        out_specs=pl.BlockSpec((nt, FEAT_TILE), lambda i, ft, eb: (i, ft)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        interpret=interpret,
    )(edge_src, edge_dst, edge_weight, inv_scale, h)
    return out.astype(h.dtype)


def _edge_dot(a, b, *, interpret: bool) -> jnp.ndarray:
    """Per-edge row dot <a[e, :], b[e, :]> -> [E], f32, feature-tiled."""
    e, f = a.shape
    grid = (e // EDGE_BLOCK, f // FEAT_TILE)
    return pl.pallas_call(
        _edge_dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((EDGE_BLOCK, FEAT_TILE), lambda eb, ft: (eb, ft)),
            pl.BlockSpec((EDGE_BLOCK, FEAT_TILE), lambda eb, ft: (eb, ft)),
        ],
        out_specs=pl.BlockSpec((EDGE_BLOCK,), lambda eb, ft: (eb,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.float32),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _aggregate_diff(interpret, h, edge_src, edge_dst, edge_weight,
                    inv_scale, src_perm):
    # src_perm is only consumed by the backward pass; in the primal it is an
    # unused parameter, so XLA dead-code-eliminates the argsort that feeds it
    # whenever the call is not differentiated.
    del src_perm
    return _aggregate(h, edge_src, edge_dst, edge_weight, inv_scale,
                      interpret=interpret)


def _aggregate_diff_fwd(interpret, h, edge_src, edge_dst, edge_weight,
                        inv_scale, src_perm):
    out = _aggregate(h, edge_src, edge_dst, edge_weight, inv_scale,
                     interpret=interpret)
    return out, (h, edge_src, edge_dst, edge_weight, inv_scale, src_perm)


def _aggregate_diff_bwd(interpret, res, g):
    h, src, dst, w, inv, perm = res
    g32 = g.astype(jnp.float32)
    ones = jnp.ones((h.shape[0],), jnp.float32)
    # h-cotangent: transpose aggregation — the same kernel over the reversed
    # (src-sorted) arc list, normalization folded into the reverse weights.
    rev_w = jnp.take(w.astype(jnp.float32) * jnp.take(inv, dst), perm)
    dh = _aggregate(g32, jnp.take(dst, perm), jnp.take(src, perm), rev_w,
                    ones, interpret=interpret).astype(h.dtype)
    # w-cotangent: per-edge row dot of h[src] with the scaled cotangent rows.
    g_scaled = g32 * inv.astype(jnp.float32)[:, None]
    dw = _edge_dot(jnp.take(h.astype(jnp.float32), src, axis=0),
                   jnp.take(g_scaled, dst, axis=0),
                   interpret=interpret).astype(w.dtype)
    zero_int = lambda x: np.zeros(x.shape, jax.dtypes.float0)
    # inv_scale is graph structure (degree normalization): zero by design.
    return (dh, zero_int(src), zero_int(dst), dw, jnp.zeros_like(inv),
            zero_int(perm))


_aggregate_diff.defvjp(_aggregate_diff_fwd, _aggregate_diff_bwd)


@functools.partial(jax.jit, static_argnames=("num_nodes", "interpret"))
def csr_aggregate_pallas(h: jnp.ndarray, edge_src: jnp.ndarray,
                         edge_dst: jnp.ndarray, edge_weight: jnp.ndarray,
                         num_nodes: int, interpret: bool = True,
                         inv_scale: jnp.ndarray | None = None,
                         src_perm: jnp.ndarray | None = None
                         ) -> jnp.ndarray:
    """Pallas path. h: [N, F] -> [N, F] (f32 accumulate, cast back).

    Differentiable w.r.t. ``h`` and ``edge_weight`` (custom VJP, see module
    docstring). ``inv_scale`` ([N], default all-ones) is multiplied into
    each output row by the kernel epilogue — pass ``1/max(degree, 1)`` to
    fuse mean normalization into the same kernel call; it is treated as
    graph structure (zero cotangent). ``src_perm`` (default
    ``argsort(edge_src)``, dead-code-eliminated unless differentiated)
    orders the reversed arc list for the transpose pass of the VJP.

    Inputs are padded by :func:`repro.kernels.ops.csr_aggregate`; this
    function requires F % FEAT_TILE == 0, E % EDGE_BLOCK == 0, and
    N % 8 == 0 when N <= NODE_TILE else N % NODE_TILE == 0.
    """
    n, f = h.shape
    e = edge_src.shape[0]
    assert (n == num_nodes and f % FEAT_TILE == 0 and e % EDGE_BLOCK == 0
            and (n % NODE_TILE == 0 if n > NODE_TILE else n % 8 == 0)), \
        (n, f, e)
    if inv_scale is None:
        inv_scale = jnp.ones((n,), jnp.float32)
    if src_perm is None:
        src_perm = jnp.argsort(edge_src)
    return _aggregate_diff(interpret, h, edge_src, edge_dst, edge_weight,
                           inv_scale.astype(jnp.float32), src_perm)
