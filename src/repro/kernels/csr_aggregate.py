"""Pallas TPU kernel: weighted neighbor aggregation (edge-list SpMM),
node-tiled, streamed, and differentiable.

The GNN hot-spot: ``out[d] += w[e] * h[src[e]]`` over a weight-0-padded
arc list. GPU implementations use shared-memory atomics; TPU has no scatter
hardware, so we ADAPT (see DESIGN.md §3): the scatter becomes a **one-hot
matmul** that feeds the MXU —

    for each node tile N_t, feature tile F_t, edge block E_b:
        G   = h[src[E_b], F_t]                        # gather   [EB, FT]
        S   = onehot(dst[E_b] - N_t.start) * w[E_b]   # scatter  [NT, EB]
        out[N_t, F_t] += S @ G                        # MXU      [NT, FT]
    after the last edge block:
        out[N_t, F_t] *= inv_scale[N_t, None]         # fused epilogue

Blocking: the grid is (node tiles × feature tiles × edge granules). The
tile sizes are no longer fixed constants — they come from a
:class:`repro.kernels.autotune.KernelConfig` (the module constants are the
untuned PR 4 point and remain the default). Two perf refinements over the
PR 4 kernel (DESIGN.md §14):

* **Degenerate-tile fast path.** ``edge_dst`` arrives sorted (the assemble
  layout), so most edge blocks touch one or two node tiles. The wrapper
  precomputes each block's dst range ``[lo, hi]`` (two tiny int32 arrays,
  passed through SMEM like ``flash_decode``'s length scalar) and the kernel
  wraps the gather + one-hot matmul in ``pl.when(block ∩ tile ≠ ∅)`` — a
  skipped block costs a scalar compare instead of an [NT, EB] × [EB, FT]
  MXU pass. Weight-0 padding arcs can only *widen* a block's range, never
  corrupt a result, so the contract below is unchanged.

* **Double-buffered edge streaming.** The edge BlockSpec loads
  ``stream × edge_block`` arcs per grid step (one larger DMA granule that
  Pallas pipelines against compute across grid steps), and the kernel
  unrolls over the ``stream`` sub-blocks, each with its own skip guard —
  bigger copies in flight, same per-matmul shapes.

The VMEM working set per step is

    (N·FT + 3·EB·S + NT·FT) · 4 B

where only the gather operand ``h`` (one [N, FT] feature column) still
scales with N; beyond N ≈ 28k padded nodes the gather operand itself would
have to be streamed from HBM — the paper's partitioning keeps partitions
far smaller (k scales with the graph). The output block index is
independent of the edge-granule grid dimension, so Pallas keeps it resident
and we accumulate across granules (init at granule 0, scale epilogue at the
last). Accumulation is f32.

Differentiation (DESIGN.md §11): ``csr_aggregate_pallas`` carries a
``jax.custom_vjp``. With A the [N, N] weighted adjacency the forward is
``out = diag(inv_scale) · A · h``, so

* the h-cotangent is ``Aᵀ · diag(inv_scale) · g`` — the *same* kernel run
  over the reversed arc list ``(dst, src)`` with weights
  ``w[e]·inv_scale[dst[e]]`` and no epilogue, re-sorted by the new
  destination (= original source) via a precomputed permutation;
* the edge-weight cotangent is the per-edge row dot
  ``dw[e] = inv_scale[dst[e]] · <g[dst[e]], h[src[e]]>`` — a small
  companion kernel (``_edge_dot_kernel``) that fuses the multiply-reduce
  over feature tiles so the [E, F] products never hit HBM;
* ``inv_scale`` (the fused degree normalization) and the arc lists are
  graph *structure*, not trainable data: their cotangents are defined as
  zero (``float0`` for the int arrays).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .autotune import KernelConfig

# The untuned PR 4 tile point — kept as module constants for back-compat
# and as the default KernelConfig; the autotuner supersedes them per
# (backend, shape-bucket).
NODE_TILE = 512
EDGE_BLOCK = 256
FEAT_TILE = 128

DEFAULT_CONFIG = KernelConfig(strategy="pallas", node_tile=NODE_TILE,
                              edge_block=EDGE_BLOCK, feat_tile=FEAT_TILE,
                              stream=1)


class ShapeContractError(ValueError):
    """A kernel input violates the F/E/N divisibility contract.

    Carries which constraint failed and the nearest valid padded shape, so
    the caller (usually a human who bypassed :mod:`repro.kernels.ops`)
    knows exactly what to pad to."""

    def __init__(self, failures, got, valid):
        self.failures = tuple(failures)
        self.got = got
        self.valid = valid
        super().__init__(
            "kernel shape contract violated: "
            + "; ".join(failures)
            + f". Got (N={got[0]}, F={got[1]}, E={got[2]}); nearest valid "
              f"padded shape is (N={valid[0]}, F={valid[1]}, E={valid[2]}). "
              "repro.kernels.ops.csr_aggregate applies this padding "
              "automatically (weight-0 arcs, see its padding contract).")


def check_shape_contract(n: int, f: int, e: int, num_nodes: int,
                         config: KernelConfig) -> None:
    """Raise :class:`ShapeContractError` naming every violated constraint."""
    ft, granule, nt = config.feat_tile, config.edge_granule, config.node_tile
    failures = []
    if n != num_nodes:
        failures.append(f"N={n} != num_nodes={num_nodes} (pad h first)")
    if f % ft != 0:
        failures.append(f"F={f} not a multiple of feat_tile={ft}")
    if e % granule != 0:
        failures.append(
            f"E={e} not a multiple of edge_block*stream="
            f"{config.edge_block}*{config.stream}={granule}")
    if n > nt:
        if n % nt != 0:
            failures.append(
                f"N={n} > node_tile={nt} but not a multiple of it")
    elif n % 8 == 0:
        pass
    else:
        failures.append(f"N={n} <= node_tile={nt} but not a multiple of 8")
    if failures:
        n_valid = (((n + nt - 1) // nt) * nt if n > nt
                   else ((n + 7) // 8) * 8)
        f_valid = ((f + ft - 1) // ft) * ft
        e_valid = ((e + granule - 1) // granule) * granule
        raise ShapeContractError(failures, (n, f, e),
                                 (n_valid, f_valid, e_valid))


def edge_block_ranges(edge_dst: jnp.ndarray, edge_block: int):
    """Per-edge-block dst range [lo, hi] (int32, [E/EB] each) feeding the
    degenerate-tile fast path. Computed on the padded arc list; weight-0
    padding arcs only widen a range — the skip is conservative."""
    blocks = edge_dst.astype(jnp.int32).reshape(-1, edge_block)
    return jnp.min(blocks, axis=1), jnp.max(blocks, axis=1)


def _agg_kernel(lo_ref, hi_ref, src_ref, dst_ref, w_ref, inv_ref, h_ref,
                out_ref, *, edge_block: int, stream: int):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    src_all = src_ref[...]                   # [EB*S] int32
    dst_all = dst_ref[...]                   # [EB*S] int32
    w_all = w_ref[...].astype(jnp.float32)   # [EB*S]
    h = h_ref[...]                           # [N, FT] full gather column
    nt = out_ref.shape[0]
    tile_lo = pl.program_id(0) * nt

    for s in range(stream):                  # unrolled sub-blocks
        blk = sb * stream + s
        lo = lo_ref[blk]
        hi = hi_ref[blk]

        # degenerate-tile fast path: skip the gather + one-hot matmul when
        # this sub-block's dst range misses the node tile entirely
        @pl.when(jnp.logical_and(hi >= tile_lo, lo < tile_lo + nt))
        def _compute(s=s):
            src = src_all[s * edge_block:(s + 1) * edge_block]
            dst = dst_all[s * edge_block:(s + 1) * edge_block]
            w = w_all[s * edge_block:(s + 1) * edge_block]
            # gather source rows: [EB, FT]
            gathered = jnp.take(h, src, axis=0).astype(jnp.float32)
            # masked one-hot scatter for THIS node tile:
            # S[i, e] = w[e] * (dst[e] == tile_start + i)  -> [NT, EB]
            rows = (jax.lax.broadcasted_iota(jnp.int32, (nt, edge_block), 0)
                    + tile_lo)
            scatter = jnp.where(rows == dst[None, :], w[None, :], 0.0)
            out_ref[...] += jax.lax.dot(scatter, gathered,
                                        preferred_element_type=jnp.float32)

    @pl.when(sb == pl.num_programs(2) - 1)
    def _epilogue():
        out_ref[...] = out_ref[...] * inv_ref[...].astype(jnp.float32)[:, None]


def _edge_dot_kernel(a_ref, b_ref, out_ref):
    ft = pl.program_id(1)

    @pl.when(ft == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.sum(a_ref[...].astype(jnp.float32)
                            * b_ref[...].astype(jnp.float32), axis=1)


def _node_tile(n: int, node_tile: int) -> int:
    return n if n <= node_tile else node_tile


def _aggregate(h, edge_src, edge_dst, edge_weight, inv_scale, *,
               interpret: bool, config: KernelConfig) -> jnp.ndarray:
    """Aligned-domain forward: one pallas_call, f32 accumulate + epilogue."""
    n, f = h.shape
    e = edge_src.shape[0]
    nt = _node_tile(n, config.node_tile)
    eb, ft_sz, stream = config.edge_block, config.feat_tile, config.stream
    ft_sz = min(ft_sz, f)
    granule = eb * stream
    grid = (n // nt, f // ft_sz, e // granule)
    lo, hi = edge_block_ranges(edge_dst, eb)
    out = pl.pallas_call(
        functools.partial(_agg_kernel, edge_block=eb, stream=stream),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),    # lo
            pl.BlockSpec(memory_space=pltpu.SMEM),    # hi
            pl.BlockSpec((granule,), lambda i, ft, sb: (sb,)),
            pl.BlockSpec((granule,), lambda i, ft, sb: (sb,)),
            pl.BlockSpec((granule,), lambda i, ft, sb: (sb,)),
            pl.BlockSpec((nt,), lambda i, ft, sb: (i,)),
            pl.BlockSpec((n, ft_sz), lambda i, ft, sb: (0, ft)),
        ],
        out_specs=pl.BlockSpec((nt, ft_sz), lambda i, ft, sb: (i, ft)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        interpret=interpret,
    )(lo, hi, edge_src, edge_dst, edge_weight, inv_scale, h)
    return out.astype(h.dtype)


def _edge_dot(a, b, *, interpret: bool, config: KernelConfig) -> jnp.ndarray:
    """Per-edge row dot <a[e, :], b[e, :]> -> [E], f32, feature-tiled."""
    e, f = a.shape
    eb, ft_sz = config.edge_block, min(config.feat_tile, f)
    grid = (e // eb, f // ft_sz)
    return pl.pallas_call(
        _edge_dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((eb, ft_sz), lambda i, ft: (i, ft)),
            pl.BlockSpec((eb, ft_sz), lambda i, ft: (i, ft)),
        ],
        out_specs=pl.BlockSpec((eb,), lambda i, ft: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.float32),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _aggregate_diff(interpret, config, h, edge_src, edge_dst, edge_weight,
                    inv_scale, src_perm):
    # src_perm is only consumed by the backward pass; in the primal it is an
    # unused parameter, so XLA dead-code-eliminates the argsort that feeds it
    # whenever the call is not differentiated.
    del src_perm
    return _aggregate(h, edge_src, edge_dst, edge_weight, inv_scale,
                      interpret=interpret, config=config)


def _aggregate_diff_fwd(interpret, config, h, edge_src, edge_dst,
                        edge_weight, inv_scale, src_perm):
    out = _aggregate(h, edge_src, edge_dst, edge_weight, inv_scale,
                     interpret=interpret, config=config)
    return out, (h, edge_src, edge_dst, edge_weight, inv_scale, src_perm)


def _aggregate_diff_bwd(interpret, config, res, g):
    h, src, dst, w, inv, perm = res
    g32 = g.astype(jnp.float32)
    ones = jnp.ones((h.shape[0],), jnp.float32)
    # h-cotangent: transpose aggregation — the same kernel over the reversed
    # (src-sorted) arc list, normalization folded into the reverse weights.
    rev_w = jnp.take(w.astype(jnp.float32) * jnp.take(inv, dst), perm)
    dh = _aggregate(g32, jnp.take(dst, perm), jnp.take(src, perm), rev_w,
                    ones, interpret=interpret, config=config).astype(h.dtype)
    # w-cotangent: per-edge row dot of h[src] with the scaled cotangent rows.
    g_scaled = g32 * inv.astype(jnp.float32)[:, None]
    dw = _edge_dot(jnp.take(h.astype(jnp.float32), src, axis=0),
                   jnp.take(g_scaled, dst, axis=0),
                   interpret=interpret, config=config).astype(w.dtype)
    zero_int = lambda x: np.zeros(x.shape, jax.dtypes.float0)
    # inv_scale is graph structure (degree normalization): zero by design.
    return (dh, zero_int(src), zero_int(dst), dw, jnp.zeros_like(inv),
            zero_int(perm))


_aggregate_diff.defvjp(_aggregate_diff_fwd, _aggregate_diff_bwd)


@functools.partial(jax.jit,
                   static_argnames=("num_nodes", "interpret", "config"))
def csr_aggregate_pallas(h: jnp.ndarray, edge_src: jnp.ndarray,
                         edge_dst: jnp.ndarray, edge_weight: jnp.ndarray,
                         num_nodes: int, interpret: bool = True,
                         inv_scale: jnp.ndarray | None = None,
                         src_perm: jnp.ndarray | None = None,
                         config: KernelConfig | None = None
                         ) -> jnp.ndarray:
    """Pallas path. h: [N, F] -> [N, F] (f32 accumulate, cast back).

    Differentiable w.r.t. ``h`` and ``edge_weight`` (custom VJP, see module
    docstring). ``inv_scale`` ([N], default all-ones) is multiplied into
    each output row by the kernel epilogue — pass ``1/max(degree, 1)`` to
    fuse mean normalization into the same kernel call; it is treated as
    graph structure (zero cotangent). ``src_perm`` (default
    ``argsort(edge_src)``, dead-code-eliminated unless differentiated)
    orders the reversed arc list for the transpose pass of the VJP.
    ``config`` (default: the fixed PR 4 tile point) selects the tuned tile
    sizes and stream factor — resolve one with
    :func:`repro.kernels.autotune.get_config`.

    Inputs are padded by :func:`repro.kernels.ops.csr_aggregate`; this
    function requires F % feat_tile == 0, E % (edge_block*stream) == 0, and
    N % 8 == 0 when N <= node_tile else N % node_tile == 0 — violations
    raise :class:`ShapeContractError` naming the failed constraint and the
    nearest valid padded shape.
    """
    if config is None:
        config = DEFAULT_CONFIG
    n, f = h.shape
    e = edge_src.shape[0]
    check_shape_contract(n, f, e, num_nodes, config)
    if inv_scale is None:
        inv_scale = jnp.ones((n,), jnp.float32)
    if src_perm is None:
        src_perm = jnp.argsort(edge_src)
    return _aggregate_diff(interpret, config, h, edge_src, edge_dst,
                           edge_weight, inv_scale.astype(jnp.float32),
                           src_perm)
