"""Pallas TPU kernel: weighted neighbor aggregation (edge-list SpMM).

The GNN hot-spot: ``out[d] += w[e] * h[src[e]]`` over a destination-sorted
arc list. GPU implementations use shared-memory atomics; TPU has no scatter
hardware, so we ADAPT (see DESIGN.md §3): the scatter becomes a **one-hot
matmul** that feeds the MXU —

    for each edge block E_b and feature tile F_t:
        G   = h[src[E_b], F_t]                      # gather   [EB, FT]
        S   = onehot(dst[E_b]) * w[E_b]             # scatter  [N,  EB]
        out[:, F_t] += S @ G                        # MXU      [N,  FT]

Blocking: the grid is (feature tiles × edge blocks); the node dimension
stays resident in VMEM (the paper's partitions are small by construction —
that is the point of partitioning — so N_pad ≤ ~8k keeps the working set
(N·FT + N·EB + EB·FT) · 4B well under the ~16 MB VMEM budget:
N=8192, FT=128, EB=256 → 4 + 8 + 0.1 ≈ 12 MB).

Accumulation is f32; the output block index is independent of the edge-block
grid dimension, so Pallas keeps it resident and we accumulate across edge
blocks (init at block 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EDGE_BLOCK = 256
FEAT_TILE = 128


def _kernel(src_ref, dst_ref, w_ref, h_ref, out_ref):
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    src = src_ref[...]                       # [EB] int32
    dst = dst_ref[...]                       # [EB] int32
    w = w_ref[...].astype(jnp.float32)       # [EB]
    h = h_ref[...]                           # [N, FT]
    n = h.shape[0]
    # gather source rows: [EB, FT]
    gathered = jnp.take(h, src, axis=0).astype(jnp.float32)
    # scatter as one-hot matmul: S[i, e] = w[e] * (dst[e] == i)  -> [N, EB]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, src.shape[0]), 0)
    scatter = jnp.where(rows == dst[None, :], w[None, :], 0.0)
    out_ref[...] += jax.lax.dot(scatter, gathered,
                                preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("num_nodes", "interpret"))
def csr_aggregate_pallas(h: jnp.ndarray, edge_src: jnp.ndarray,
                         edge_dst: jnp.ndarray, edge_weight: jnp.ndarray,
                         num_nodes: int, interpret: bool = True
                         ) -> jnp.ndarray:
    """Pallas path. h: [N, F] -> [N, F] (f32 accumulate, cast back).

    Inputs are padded by :func:`repro.kernels.ops.csr_aggregate`; this
    function requires N % 8 == 0, F % FEAT_TILE == 0, E % EDGE_BLOCK == 0.
    """
    n, f = h.shape
    e = edge_src.shape[0]
    assert n == num_nodes and f % FEAT_TILE == 0 and e % EDGE_BLOCK == 0, \
        (n, f, e)
    grid = (f // FEAT_TILE, e // EDGE_BLOCK)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((EDGE_BLOCK,), lambda ft, eb: (eb,)),
            pl.BlockSpec((EDGE_BLOCK,), lambda ft, eb: (eb,)),
            pl.BlockSpec((EDGE_BLOCK,), lambda ft, eb: (eb,)),
            pl.BlockSpec((n, FEAT_TILE), lambda ft, eb: (0, ft)),
        ],
        out_specs=pl.BlockSpec((n, FEAT_TILE), lambda ft, eb: (0, ft)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        interpret=interpret,
    )(edge_src, edge_dst, edge_weight, h)
    return out.astype(h.dtype)
