"""Partition artifact store — partition once, reuse forever.

GraphStorm-style regression workflows partition a graph once, persist the
result, and share it across every downstream training run; this module gives
the repo the same shape (DESIGN.md §1). Two artifact kinds live under one
cache directory as content-addressed ``.npz`` bundles:

* **labels bundle** — the raw partition assignment, keyed by
  ``(graph_hash, method, k, seed)``. This is the expensive stage (Leiden +
  fusion is minutes on paper-scale graphs), so it is cached independently of
  the assembly scheme: ``inner`` and ``repli`` runs share one partitioning.
* **batch bundle** — the padded :class:`~repro.core.PartitionBatch` tensors
  (plus the halo exchange spec when requested), keyed additionally by
  ``scheme``.

Filenames embed a human-readable prefix plus the first 16 hex chars of the
key digest; the digest covers a format-version field, so bumping
``ARTIFACT_VERSION`` silently invalidates stale bundles. Writes are atomic
(tmp file + ``os.replace``); loads validate the embedded metadata against the
requested key and treat any mismatch as a miss.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import tempfile
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core import (Graph, HaloExchangeSpec, PartitionBatch,
                        build_halo_exchange, build_partition_batch,
                        get_partitioner)

from .datasets import graph_fingerprint

__all__ = ["ARTIFACT_VERSION", "ArtifactBundle", "PartitionArtifactStore",
           "compute_bundle"]

log = logging.getLogger("repro.pipeline")

ARTIFACT_VERSION = 1

_BATCH_FIELDS = ("node_ids", "node_mask", "owned_mask", "edge_src",
                 "edge_dst", "edge_weight", "in_degree")


@dataclasses.dataclass(frozen=True)
class ArtifactBundle:
    """Everything the training stage needs, plus cache provenance."""
    labels: np.ndarray
    batch: PartitionBatch
    halo: Optional[HaloExchangeSpec]
    labels_hit: bool
    batch_hit: bool
    labels_path: Optional[str]
    batch_path: Optional[str]
    partition_seconds: float
    assemble_seconds: float


def _digest(meta: Dict[str, Any]) -> str:
    import hashlib
    blob = json.dumps(meta, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def compute_bundle(g: Graph, method: str, k: int, seed: int, scheme: str,
                   with_halo: bool = False,
                   labels: Optional[np.ndarray] = None) -> ArtifactBundle:
    """Storeless path: run partitioner + assembly directly (no caching)."""
    t0 = time.time()
    if labels is None:
        labels = get_partitioner(method)(g, k, seed=seed)
    t_part = time.time() - t0
    t0 = time.time()
    batch = build_partition_batch(g, labels, scheme=scheme)
    halo = build_halo_exchange(g, labels, batch) if with_halo else None
    return ArtifactBundle(labels=labels, batch=batch, halo=halo,
                          labels_hit=False, batch_hit=False,
                          labels_path=None, batch_path=None,
                          partition_seconds=t_part,
                          assemble_seconds=time.time() - t0)


class PartitionArtifactStore:
    """Load-or-compute cache of partition artifacts under ``cache_dir``."""

    def __init__(self, cache_dir: str):
        self.cache_dir = os.path.abspath(os.path.expanduser(cache_dir))
        os.makedirs(self.cache_dir, exist_ok=True)

    # ----- key/paths -------------------------------------------------------
    def _labels_meta(self, graph_hash: str, method: str, k: int, seed: int
                     ) -> Dict[str, Any]:
        return {"kind": "labels", "version": ARTIFACT_VERSION,
                "graph": graph_hash, "method": method, "k": int(k),
                "seed": int(seed)}

    def _batch_meta(self, graph_hash: str, method: str, k: int, seed: int,
                    scheme: str) -> Dict[str, Any]:
        return {"kind": "batch", "version": ARTIFACT_VERSION,
                "graph": graph_hash, "method": method, "k": int(k),
                "seed": int(seed), "scheme": scheme}

    def _path(self, meta: Dict[str, Any]) -> str:
        if meta["kind"] == "labels":
            stem = f"labels-{meta['method']}-k{meta['k']}-s{meta['seed']}"
        else:
            stem = (f"batch-{meta['method']}-k{meta['k']}-s{meta['seed']}"
                    f"-{meta['scheme']}")
        return os.path.join(self.cache_dir, f"{stem}-{_digest(meta)}.npz")

    # ----- low-level IO ----------------------------------------------------
    @staticmethod
    def _atomic_savez(path: str, **arrays) -> None:
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez_compressed(f, **arrays)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    @staticmethod
    def _load_npz(path: str, meta: Dict[str, Any]
                  ) -> Optional[Dict[str, np.ndarray]]:
        if not os.path.exists(path):
            return None
        try:
            with np.load(path, allow_pickle=False) as z:
                data = {k: z[k] for k in z.files}
            stored = json.loads(str(data.pop("meta_json")))
        except (OSError, ValueError, KeyError) as e:
            log.warning("unreadable artifact %s (%r) — recomputing", path, e)
            return None
        if stored != meta:
            log.warning("stale artifact %s (key mismatch) — recomputing",
                        path)
            return None
        return data

    # ----- labels ----------------------------------------------------------
    def load_or_partition(self, g: Graph, method: str, k: int, seed: int,
                          graph_hash: Optional[str] = None
                          ) -> Tuple[np.ndarray, bool, str, float]:
        """Returns (labels, cache_hit, path, partition_seconds)."""
        graph_hash = graph_hash or graph_fingerprint(g)
        meta = self._labels_meta(graph_hash, method, k, seed)
        path = self._path(meta)
        data = self._load_npz(path, meta)
        if data is not None:
            log.info("partition cache HIT: %s (method=%s k=%d seed=%d) — "
                     "skipping re-partition", path, method, k, seed)
            return data["labels"].astype(np.int64), True, path, 0.0
        log.info("partition cache MISS: computing %s k=%d seed=%d",
                 method, k, seed)
        t0 = time.time()
        labels = get_partitioner(method)(g, k, seed=seed)
        secs = time.time() - t0
        self._atomic_savez(path, labels=labels.astype(np.int64),
                           meta_json=np.asarray(json.dumps(meta)))
        log.info("partition artifact saved: %s (%.2fs)", path, secs)
        return labels, False, path, secs

    # ----- batch -----------------------------------------------------------
    def load_or_assemble(self, g: Graph, labels: np.ndarray, method: str,
                         k: int, seed: int, scheme: str,
                         with_halo: bool = False,
                         graph_hash: Optional[str] = None
                         ) -> Tuple[PartitionBatch, Optional[HaloExchangeSpec],
                                    bool, str, float]:
        """Returns (batch, halo, cache_hit, path, assemble_seconds)."""
        graph_hash = graph_hash or graph_fingerprint(g)
        meta = self._batch_meta(graph_hash, method, k, seed, scheme)
        path = self._path(meta)
        data = self._load_npz(path, meta)
        if data is not None:
            batch = PartitionBatch(
                **{f: data[f] for f in _BATCH_FIELDS},
                n_pad=int(data["n_pad"]), e_pad=int(data["e_pad"]))
            halo = None
            if "halo_send_rows" in data:
                halo = HaloExchangeSpec(send_rows=data["halo_send_rows"],
                                        recv_rows=data["halo_recv_rows"],
                                        h_pad=int(data["halo_h_pad"]))
            if with_halo and halo is None:
                # augment the cached bundle in place; the batch itself is
                # still a hit — only the (cheap) halo plan is recomputed.
                log.info("batch cache HIT (augmenting with halo spec): %s",
                         path)
                halo = build_halo_exchange(g, labels, batch)
                self._save_batch(path, meta, batch, halo)
            else:
                log.info("batch cache HIT: %s", path)
            return batch, halo, True, path, 0.0
        log.info("batch cache MISS: assembling scheme=%s", scheme)
        t0 = time.time()
        batch = build_partition_batch(g, labels, scheme=scheme)
        halo = build_halo_exchange(g, labels, batch) if with_halo else None
        secs = time.time() - t0
        self._save_batch(path, meta, batch, halo)
        return batch, halo, False, path, secs

    def _save_batch(self, path: str, meta: Dict[str, Any],
                    batch: PartitionBatch,
                    halo: Optional[HaloExchangeSpec]) -> None:
        arrays = {f: getattr(batch, f) for f in _BATCH_FIELDS}
        arrays["n_pad"] = np.int64(batch.n_pad)
        arrays["e_pad"] = np.int64(batch.e_pad)
        if halo is not None:
            arrays["halo_send_rows"] = halo.send_rows
            arrays["halo_recv_rows"] = halo.recv_rows
            arrays["halo_h_pad"] = np.int64(halo.h_pad)
        self._atomic_savez(path, meta_json=np.asarray(json.dumps(meta)),
                           **arrays)

    # ----- the one-call API ------------------------------------------------
    def load_or_compute(self, g: Graph, method: str, k: int, seed: int,
                        scheme: str, with_halo: bool = False
                        ) -> ArtifactBundle:
        graph_hash = graph_fingerprint(g)
        labels, lhit, lpath, t_part = self.load_or_partition(
            g, method, k, seed, graph_hash=graph_hash)
        batch, halo, bhit, bpath, t_asm = self.load_or_assemble(
            g, labels, method, k, seed, scheme, with_halo=with_halo,
            graph_hash=graph_hash)
        return ArtifactBundle(labels=labels, batch=batch, halo=halo,
                              labels_hit=lhit, batch_hit=bhit,
                              labels_path=lpath, batch_path=bpath,
                              partition_seconds=t_part,
                              assemble_seconds=t_asm)

    # ----- maintenance -----------------------------------------------------
    def entries(self):
        """(filename, size_bytes) for every bundle in the cache."""
        out = []
        for name in sorted(os.listdir(self.cache_dir)):
            if name.endswith(".npz"):
                p = os.path.join(self.cache_dir, name)
                out.append((name, os.path.getsize(p)))
        return out

    def clear(self) -> int:
        n = 0
        for name, _ in self.entries():
            os.unlink(os.path.join(self.cache_dir, name))
            n += 1
        return n
