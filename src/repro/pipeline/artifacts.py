"""Partition artifact store — partition once, reuse forever.

GraphStorm-style regression workflows partition a graph once, persist the
result, and share it across every downstream training run; this module gives
the repo the same shape (DESIGN.md §1). Two artifact kinds live under one
cache directory as content-addressed bundle *directories* (``meta.json`` +
one ``.npy`` per array, written atomically via
:class:`~repro.core.atomic_directory`):

* **labels bundle** — the raw partition assignment, keyed by
  ``(graph_hash, canonical spec, config fingerprint, k, seed)``. This is the
  expensive stage (Leiden + fusion is minutes on paper-scale graphs), so it
  is cached independently of the assembly scheme: ``inner`` and ``repli``
  runs share one partitioning.
* **batch bundle** — the padded :class:`~repro.core.PartitionBatch` tensors
  (plus the halo exchange spec when requested), keyed additionally by
  ``scheme``.

``method`` accepts any Partitioner API v2 spec string (DESIGN.md §9) —
``"metis"``, ``"lpa+f(alpha=0.1)"``, ``"leiden_fusion(resolution=0.5)"`` —
or an already-parsed :class:`~repro.core.PartitionerSpec`. The cache key
embeds the spec's config *fingerprint* (a hash over the fully-resolved
config, defaults included), so differently-parameterized runs of the same
method land in distinct bundles; v1 keyed only ``(method, k, seed)`` and
collided them.

Bundle names embed a human-readable prefix plus the first 16 hex chars of
the key digest; the digest covers a format-version field, so bumping
``ARTIFACT_VERSION`` silently invalidates stale bundles (v2: fingerprint
keys; v3: the vectorized partitioning engine visits nodes in a different
order than the v2 Python queue, so v2 labels are stale for identical
fingerprints; v5: monolithic compressed ``.npz`` bundles became directory
bundles whose batch tensors load with ``mmap_mode="r"`` — each field is a
``[k, ...]`` array whose row ``p`` is partition ``p``'s physical shard, so
one partition's tensors page in without materializing the other ``k-1``.
Pre-v5 ``.npz`` bundles — including v4-keyed ones — degrade to cache
misses, never wrong hits). Writes are atomic (tmp directory +
``os.replace``); loads validate the embedded metadata against the requested
key and treat any mismatch as a miss.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import re
import tempfile
import time
from typing import Any, Dict, Optional, Tuple, Union

import numpy as np

from repro.core import (Graph, HaloExchangeSpec, PartitionBatch,
                        PartitionerSpec, atomic_directory,
                        build_halo_exchange, build_partition_batch,
                        partition_from_spec)

from .datasets import graph_fingerprint

__all__ = ["ARTIFACT_VERSION", "ArtifactBundle", "PartitionArtifactStore",
           "compute_bundle"]

log = logging.getLogger("repro.pipeline")

ARTIFACT_VERSION = 5

_BATCH_FIELDS = ("node_ids", "node_mask", "owned_mask", "edge_src",
                 "edge_dst", "edge_weight", "in_degree")

SpecLike = Union[str, PartitionerSpec]


@dataclasses.dataclass(frozen=True)
class ArtifactBundle:
    """Everything the training stage needs, plus cache provenance."""
    labels: np.ndarray
    batch: PartitionBatch
    halo: Optional[HaloExchangeSpec]
    labels_hit: bool
    batch_hit: bool
    labels_path: Optional[str]
    batch_path: Optional[str]
    partition_seconds: float
    assemble_seconds: float
    spec: str = ""                  # canonical partitioner spec
    fingerprint: str = ""           # the spec's config fingerprint


def _digest(meta: Dict[str, Any]) -> str:
    import hashlib
    blob = json.dumps(meta, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def _spec_slug(spec: PartitionerSpec) -> str:
    """Filesystem-safe, human-readable prefix from the canonical spec."""
    slug = re.sub(r"[^A-Za-z0-9_.+=-]+", "_", spec.canonical()).strip("_")
    return slug[:60] or "partition"


def compute_bundle(g: Graph, method: SpecLike, k: int, seed: int,
                   scheme: str, with_halo: bool = False,
                   labels: Optional[np.ndarray] = None) -> ArtifactBundle:
    """Storeless path: run partitioner + assembly directly (no caching)."""
    spec = PartitionerSpec.parse(method)
    t_part = 0.0
    if labels is None:
        result = partition_from_spec(g, spec, k, seed)
        labels, t_part = result.labels, result.seconds
    t0 = time.time()
    batch = build_partition_batch(g, labels, scheme=scheme)
    halo = build_halo_exchange(g, labels, batch) if with_halo else None
    return ArtifactBundle(labels=labels, batch=batch, halo=halo,
                          labels_hit=False, batch_hit=False,
                          labels_path=None, batch_path=None,
                          partition_seconds=t_part,
                          assemble_seconds=time.time() - t0,
                          spec=spec.canonical(),
                          fingerprint=spec.fingerprint())


class PartitionArtifactStore:
    """Load-or-compute cache of partition artifacts under ``cache_dir``."""

    def __init__(self, cache_dir: str):
        self.cache_dir = os.path.abspath(os.path.expanduser(cache_dir))
        os.makedirs(self.cache_dir, exist_ok=True)

    # ----- key/paths -------------------------------------------------------
    def _labels_meta(self, graph_hash: str, spec: PartitionerSpec, k: int,
                     seed: int) -> Dict[str, Any]:
        return {"kind": "labels", "version": ARTIFACT_VERSION,
                "graph": graph_hash, "spec": spec.canonical(),
                "config_fp": spec.fingerprint(), "k": int(k),
                "seed": int(seed)}

    def _batch_meta(self, graph_hash: str, spec: PartitionerSpec, k: int,
                    seed: int, scheme: str) -> Dict[str, Any]:
        return {"kind": "batch", "version": ARTIFACT_VERSION,
                "graph": graph_hash, "spec": spec.canonical(),
                "config_fp": spec.fingerprint(), "k": int(k),
                "seed": int(seed), "scheme": scheme}

    def _path(self, meta: Dict[str, Any], spec: PartitionerSpec) -> str:
        """Bundle directory path (v5+; pre-v5 bundles were ``.npz`` files
        whose digests keyed the old versions — they never collide with a
        v5 path and simply age out as misses)."""
        stem = f"{meta['kind']}-{_spec_slug(spec)}-k{meta['k']}-s{meta['seed']}"
        if meta["kind"] == "batch":
            stem += f"-{meta['scheme']}"
        return os.path.join(self.cache_dir, f"{stem}-{_digest(meta)}")

    # ----- low-level IO ----------------------------------------------------
    @staticmethod
    def _atomic_save_bundle(path: str, meta: Dict[str, Any],
                            arrays: Dict[str, np.ndarray]) -> None:
        """Write a bundle directory atomically: ``meta.json`` + one plain
        ``.npy`` per array (mmap-loadable, unlike a compressed npz)."""
        with atomic_directory(path) as tmp:
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f, indent=1, sort_keys=True)
            for name, arr in arrays.items():
                np.save(os.path.join(tmp, name + ".npy"), arr)

    @staticmethod
    def _load_bundle(path: str, meta: Dict[str, Any],
                     required: Tuple[str, ...] = ()
                     ) -> Optional[Dict[str, np.ndarray]]:
        """Open a bundle directory; arrays come back memory-mapped
        (read-only). Any mismatch/corruption degrades to a miss (None)."""
        if not os.path.isdir(path):
            return None
        try:
            with open(os.path.join(path, "meta.json")) as f:
                stored = json.load(f)
        except (OSError, ValueError) as e:
            log.warning("unreadable artifact %s (%r) — recomputing", path, e)
            return None
        if stored != meta:
            log.warning("stale artifact %s (key mismatch) — recomputing",
                        path)
            return None
        data: Dict[str, np.ndarray] = {}
        try:
            for name in os.listdir(path):
                if name.endswith(".npy"):
                    data[name[:-4]] = np.load(os.path.join(path, name),
                                              mmap_mode="r",
                                              allow_pickle=False)
        except (OSError, ValueError) as e:
            log.warning("unreadable artifact %s (%r) — recomputing", path, e)
            return None
        missing = [k for k in required if k not in data]
        if missing:
            log.warning("incomplete artifact %s (missing %s) — recomputing",
                        path, missing)
            return None
        return data

    # Legacy (pre-v5) npz helpers. Production code no longer writes npz
    # bundles; these stay so the version-skew tests can forge old-format
    # artifacts and the cache maintenance commands can list/clear them.
    @staticmethod
    def _atomic_savez(path: str, **arrays) -> None:
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez_compressed(f, **arrays)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    @staticmethod
    def _load_npz(path: str, meta: Dict[str, Any]
                  ) -> Optional[Dict[str, np.ndarray]]:
        if not os.path.exists(path):
            return None
        try:
            with np.load(path, allow_pickle=False) as z:
                data = {k: z[k] for k in z.files}
            stored = json.loads(str(data.pop("meta_json")))
        except (OSError, ValueError, KeyError) as e:
            log.warning("unreadable artifact %s (%r) — recomputing", path, e)
            return None
        if stored != meta:
            log.warning("stale artifact %s (key mismatch) — recomputing",
                        path)
            return None
        return data

    # ----- labels ----------------------------------------------------------
    def load_or_partition(self, g: Graph, method: SpecLike, k: int, seed: int,
                          graph_hash: Optional[str] = None
                          ) -> Tuple[np.ndarray, bool, str, float]:
        """Returns (labels, cache_hit, path, partition_seconds)."""
        spec = PartitionerSpec.parse(method)
        graph_hash = graph_hash or graph_fingerprint(g)
        meta = self._labels_meta(graph_hash, spec, k, seed)
        path = self._path(meta, spec)
        data = self._load_bundle(path, meta, required=("labels",))
        if data is not None:
            log.info("partition cache HIT: %s (spec=%s fp=%s k=%d seed=%d) "
                     "— skipping re-partition", path, spec.canonical(),
                     spec.fingerprint(), k, seed)
            return np.asarray(data["labels"], dtype=np.int64), True, path, 0.0
        log.info("partition cache MISS: computing %s k=%d seed=%d",
                 spec.canonical(), k, seed)
        result = partition_from_spec(g, spec, k, seed)
        self._atomic_save_bundle(path, meta, {"labels": result.labels})
        log.info("partition artifact saved: %s (%.2fs)", path,
                 result.seconds)
        return result.labels, False, path, result.seconds

    # ----- batch -----------------------------------------------------------
    def load_or_assemble(self, g: Graph, labels: np.ndarray,
                         method: SpecLike, k: int, seed: int, scheme: str,
                         with_halo: bool = False,
                         graph_hash: Optional[str] = None
                         ) -> Tuple[PartitionBatch, Optional[HaloExchangeSpec],
                                    bool, str, float]:
        """Returns (batch, halo, cache_hit, path, assemble_seconds)."""
        spec = PartitionerSpec.parse(method)
        graph_hash = graph_hash or graph_fingerprint(g)
        meta = self._batch_meta(graph_hash, spec, k, seed, scheme)
        path = self._path(meta, spec)
        data = self._load_bundle(path, meta,
                                 required=_BATCH_FIELDS + ("n_pad", "e_pad"))
        if data is not None:
            # fields arrive memory-mapped: row p of each [k, ...] array is
            # partition p's shard, paged in only when that partition trains
            batch = PartitionBatch(
                **{f: data[f] for f in _BATCH_FIELDS},
                n_pad=int(data["n_pad"]), e_pad=int(data["e_pad"]))
            halo = None
            if "halo_send_rows" in data:
                halo = HaloExchangeSpec(send_rows=data["halo_send_rows"],
                                        recv_rows=data["halo_recv_rows"],
                                        h_pad=int(data["halo_h_pad"]))
            if with_halo and halo is None:
                # augment the cached bundle in place; the batch itself is
                # still a hit — only the (cheap) halo plan is recomputed.
                log.info("batch cache HIT (augmenting with halo spec): %s",
                         path)
                halo = build_halo_exchange(g, labels, batch)
                self._save_batch(path, meta, batch, halo)
            else:
                log.info("batch cache HIT: %s", path)
            return batch, halo, True, path, 0.0
        log.info("batch cache MISS: assembling scheme=%s", scheme)
        t0 = time.time()
        batch = build_partition_batch(g, labels, scheme=scheme)
        halo = build_halo_exchange(g, labels, batch) if with_halo else None
        secs = time.time() - t0
        self._save_batch(path, meta, batch, halo)
        return batch, halo, False, path, secs

    def _save_batch(self, path: str, meta: Dict[str, Any],
                    batch: PartitionBatch,
                    halo: Optional[HaloExchangeSpec]) -> None:
        arrays = {f: np.asarray(getattr(batch, f)) for f in _BATCH_FIELDS}
        arrays["n_pad"] = np.int64(batch.n_pad)
        arrays["e_pad"] = np.int64(batch.e_pad)
        if halo is not None:
            arrays["halo_send_rows"] = np.asarray(halo.send_rows)
            arrays["halo_recv_rows"] = np.asarray(halo.recv_rows)
            arrays["halo_h_pad"] = np.int64(halo.h_pad)
        self._atomic_save_bundle(path, meta, arrays)

    # ----- the one-call API ------------------------------------------------
    def load_or_compute(self, g: Graph, method: SpecLike, k: int, seed: int,
                        scheme: str, with_halo: bool = False
                        ) -> ArtifactBundle:
        spec = PartitionerSpec.parse(method)
        graph_hash = graph_fingerprint(g)
        labels, lhit, lpath, t_part = self.load_or_partition(
            g, spec, k, seed, graph_hash=graph_hash)
        batch, halo, bhit, bpath, t_asm = self.load_or_assemble(
            g, labels, spec, k, seed, scheme, with_halo=with_halo,
            graph_hash=graph_hash)
        return ArtifactBundle(labels=labels, batch=batch, halo=halo,
                              labels_hit=lhit, batch_hit=bhit,
                              labels_path=lpath, batch_path=bpath,
                              partition_seconds=t_part,
                              assemble_seconds=t_asm,
                              spec=spec.canonical(),
                              fingerprint=spec.fingerprint())

    # ----- maintenance -----------------------------------------------------
    def entries(self):
        """(name, size_bytes) for every bundle in the cache — v5 bundle
        directories plus any legacy pre-v5 ``.npz`` files."""
        out = []
        for name in sorted(os.listdir(self.cache_dir)):
            p = os.path.join(self.cache_dir, name)
            if os.path.isdir(p) and ".tmp-" not in name:
                size = sum(os.path.getsize(os.path.join(root, f))
                           for root, _, fnames in os.walk(p) for f in fnames)
                out.append((name, size))
            elif name.endswith(".npz"):
                out.append((name, os.path.getsize(p)))
        return out

    def clear(self) -> int:
        import shutil
        n = 0
        for name, _ in self.entries():
            p = os.path.join(self.cache_dir, name)
            if os.path.isdir(p):
                shutil.rmtree(p)
            else:
                os.unlink(p)
            n += 1
        return n
