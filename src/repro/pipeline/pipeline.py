"""The end-to-end pipeline orchestrator (DESIGN.md §1).

Chains the paper's three stages behind one call:

    dataset -> partition (cached) -> per-partition GNN training -> model
    integration -> embedding assembly -> MLP classifier eval

and returns a single :class:`PipelineReport` carrying partition quality,
collective bytes of the lowered train step, classification accuracy, and
per-stage timings. Training mode is ``local`` (the paper's communication-free
scheme), ``sync`` (the DGL-style halo-exchange baseline), or ``stale``
(periodic halo exchange every ``sync_period`` epochs — the comm-vs-accuracy
middle ground, DESIGN.md §12). ``integrate`` optionally parameter-averages
(``model_avg``) or ensembles the k per-partition models before assembly.

Every stage runs under a ``repro.obs`` span (``pipeline.dataset``,
``pipeline.partition``, ``pipeline.train``, ``pipeline.classifier``, ...)
nested in one ``pipeline.total`` root. ``PipelineReport.timings`` is a view
over those span durations — when tracing is enabled each timing IS the
corresponding span's duration (pinned by ``tests/test_obs.py``); when
disabled, the same windows are measured with bare ``perf_counter`` pairs so
the dict stays API-compatible at zero tracing cost (DESIGN.md §16).
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import time
from typing import Any, Dict, Mapping, Optional

from repro import obs
from repro.core import (INTEGRATION_KINDS, NodeDataset, PartitionerSpec,
                        evaluate_partition)
from repro.gnn import (GNNConfig, stale_bytes_per_epoch,
                       stale_exchange_epochs, train_classifier, train_local,
                       train_stale, train_sync)

from .artifacts import ArtifactBundle, PartitionArtifactStore, compute_bundle
from .datasets import get_dataset

__all__ = ["PipelineConfig", "PipelineReport", "Pipeline"]

log = logging.getLogger("repro.pipeline")


@contextlib.contextmanager
def _stage_span(timings: Dict[str, float], key: str, name: str,
                **attrs: Any):
    """Time one pipeline stage into ``timings[key]``.

    Tracing enabled: the timing is exactly the span's recorded duration, so
    ``timings`` is a faithful view over the trace. Disabled: a plain
    ``perf_counter`` pair over the identical window.
    """
    if obs.enabled():
        with obs.span(name, **attrs) as sp:
            yield sp
        timings[key] = sp.duration
    else:
        t0 = time.perf_counter()
        yield obs.span(name)     # the shared no-op span
        timings[key] = time.perf_counter() - t0


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """One run of the end-to-end pipeline. Mirrors the CLI flags 1:1."""
    dataset: str = "arxiv-like"
    method: str = "leiden_fusion"   # partitioner spec string (DESIGN.md §9),
                                    # e.g. "metis", "lpa+f(alpha=0.1)",
                                    # "leiden_fusion(resolution=0.5)"
    k: int = 8
    seed: int = 0
    scheme: str = "repli"           # "inner" | "repli" (sync/stale force repli)
    mode: str = "local"             # "local" | "sync" | "stale"
    sync_period: int = 4            # stale mode: exchange halos every N
                                    # epochs (1 ≡ sync; 0 = never ≡ local)
    integrate: str = "none"         # "none" | "model_avg" | "ensemble" —
                                    # aggregate the k models pre-assembly
    model: str = "gcn"              # "gcn" | "sage"
    use_kernel: bool = False        # route GNN layers through the kernel
                                    # dispatcher (DESIGN.md §3/§11/§14);
                                    # differentiable, so every training
                                    # mode supports it
    kernel_autotune: bool = False   # sweep the kernel search space for this
                                    # run's shape buckets before training
                                    # (cached on disk; implies use_kernel
                                    # semantics only when use_kernel=True)
    hidden_dim: int = 128
    embed_dim: int = 128
    num_layers: int = 3
    dropout: float = 0.3
    epochs: int = 60
    lr: float = 5e-3
    classifier_epochs: int = 150    # <= 0 skips the classifier stage
    classifier_hidden: int = 256
    cache_dir: Optional[str] = None     # None disables the artifact cache
    checkpoint_dir: Optional[str] = None
    serving_dir: Optional[str] = None   # export a serving bundle here
                                        # (repro.serving, DESIGN.md §13);
                                        # requires the classifier stage
    collect_hlo: bool = True        # lower+compile once to count collectives
    shard_data_axis: bool = True    # local mode: shard k over the mesh
    low_memory: bool = False        # local mode: train partitions one at a
                                    # time (same math, ~1/k the transient
                                    # footprint; forces unsharded + no HLO
                                    # collection — DESIGN.md §15)
                                    # `data` axis; False forces unsharded
                                    # (sequential) execution, e.g. for
                                    # per-partition wall-time measurement
    jax_profile_dir: Optional[str] = None   # start a jax.profiler session
                                            # around the training stage and
                                            # write it here (DESIGN.md §16)
    dataset_kwargs: Mapping[str, Any] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass(frozen=True)
class PipelineReport:
    """Structured result of one pipeline run."""
    config: Dict[str, Any]
    dataset: str
    num_nodes: int
    num_edges: int
    num_devices: int
    partition: Dict[str, Any]        # PartitionReport.as_dict()
    partition_cache_hit: bool
    batch_cache_hit: bool
    artifact_paths: Dict[str, Optional[str]]
    shapes: Dict[str, int]           # k, n_pad, e_pad
    collectives: Dict[str, int]      # collective_bytes() of the train step
    accuracy: Dict[str, float]       # train/val/test (empty if skipped)
    timings: Dict[str, float]
    checkpoint_path: Optional[str] = None
    partition_fingerprint: Optional[str] = None   # spec config fingerprint
    serving_path: Optional[str] = None            # exported serving bundle
    kernel: Optional[Dict[str, Any]] = None       # resolved KernelConfig per
                                                  # layer-input width
                                                  # (use_kernel runs only)

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        c = self.config
        lines = ["PipelineReport"]
        lines.append(f"  dataset      {self.dataset} (n={self.num_nodes}, "
                     f"edges={self.num_edges})")
        hit = "HIT" if self.partition_cache_hit else "miss"
        fp = f" fp={self.partition_fingerprint}" \
            if self.partition_fingerprint else ""
        lines.append(f"  partition    {c['method']} k={c['k']} "
                     f"seed={c['seed']}{fp} [cache {hit}]")
        p = self.partition
        lines.append(f"               cut={p['edge_cut_pct']:.1f}% "
                     f"components={p['total_components']} "
                     f"isolated={p['total_isolated']} "
                     f"balance={p['node_balance']:.2f} "
                     f"replication={p['replication_factor']:.2f}")
        bhit = "HIT" if self.batch_cache_hit else "miss"
        lines.append(f"  assembly     scheme={c['scheme']} "
                     f"n_pad={self.shapes['n_pad']} "
                     f"e_pad={self.shapes['e_pad']} [cache {bhit}]")
        agg = "jnp"
        if c.get("use_kernel"):
            strategies = sorted({v["strategy"]
                                 for v in (self.kernel or {}).values()})
            agg = "kernel[" + ",".join(strategies) + "]" if strategies \
                else "pallas-kernel"
        mode = c["mode"]
        if mode == "stale":
            period = c.get("sync_period", 0)
            mode = f"stale(period={period if period else '∞'})"
        lines.append(f"  training     mode={mode} model={c['model']} "
                     f"layers={c['num_layers']} epochs={c['epochs']} "
                     f"aggregation={agg} devices={self.num_devices}")
        if c.get("integrate", "none") != "none":
            lines.append(f"  integration  {c['integrate']} over k={c['k']} "
                         f"partition models (pre-assembly)")
        if self.collectives:
            lines.append(f"  collectives  {self.collectives['total']} "
                         f"bytes/step (all-gather="
                         f"{self.collectives['all-gather']}, all-reduce="
                         f"{self.collectives['all-reduce']})")
            if c["mode"] == "stale":
                lines.append(
                    f"  stale comm   "
                    f"{self.collectives.get('per_epoch_avg', 0)} bytes/epoch "
                    f"avg ({self.collectives.get('n_exchange_epochs', 0)}/"
                    f"{c['epochs']} exchange epochs, between-exchange step="
                    f"{self.collectives.get('stale_step_total', 0)} bytes)")
        if self.accuracy:
            lines.append(f"  accuracy     train={self.accuracy['train']:.3f} "
                         f"val={self.accuracy['val']:.3f} "
                         f"test={self.accuracy['test']:.3f}")
        if self.checkpoint_path:
            lines.append(f"  checkpoint   {self.checkpoint_path}")
        if self.serving_path:
            lines.append(f"  serving      {self.serving_path}")
        t = self.timings
        lines.append("  timings      " + " ".join(
            f"{k}={v:.2f}s" for k, v in t.items()))
        return "\n".join(lines)


class Pipeline:
    """Orchestrates partition -> train -> assemble -> eval.

    ``store``/``mesh`` may be injected (the benchmarks share one store across
    every grid point); otherwise they are derived from the config /
    ``repro.launch.mesh``.
    """

    def __init__(self, config: PipelineConfig,
                 store: Optional[PartitionArtifactStore] = None,
                 mesh=None):
        self.config = config
        if store is None and config.cache_dir:
            store = PartitionArtifactStore(config.cache_dir)
        self.store = store
        self.mesh = mesh

    # ------------------------------------------------------------------
    def _resolve_mesh(self, k: int):
        """Mesh for the train step, from repro.launch when not injected."""
        import jax
        from repro.launch.mesh import make_local_mesh
        mesh = self.mesh
        if self.config.mode == "local" and not self.config.shard_data_axis:
            return None
        if mesh is None:
            mesh = make_local_mesh()
        data = int(mesh.shape["data"])
        if self.config.mode in ("sync", "stale"):
            return mesh          # train_sync/train_stale validate data == k
        if k % data != 0:
            log.warning("k=%d not divisible by mesh data axis %d — "
                        "running unsharded", k, data)
            return None
        return mesh
    # ------------------------------------------------------------------
    def run(self, ds: Optional[NodeDataset] = None) -> PipelineReport:
        cfg = self.config
        if cfg.mode not in ("local", "sync", "stale"):
            raise ValueError(
                f"mode must be local|sync|stale, got {cfg.mode!r}")
        if cfg.k < 1:
            raise ValueError(f"k must be >= 1, got {cfg.k}")
        if cfg.sync_period < 0:
            raise ValueError(
                f"sync_period must be >= 0 (0 = never exchange), "
                f"got {cfg.sync_period}")
        if cfg.integrate not in INTEGRATION_KINDS:
            raise ValueError(
                f"integrate must be one of {INTEGRATION_KINDS}, "
                f"got {cfg.integrate!r}")
        if cfg.serving_dir and cfg.classifier_epochs <= 0:
            raise ValueError(
                "serving_dir requires the classifier stage "
                "(classifier_epochs > 0): the serving bundle carries the "
                "trained classifier and its offline answer key")
        # resolve the partitioner spec up front: a bad method string fails
        # here, before any dataset/partition work happens
        spec = PartitionerSpec.parse(cfg.method)
        scheme = cfg.scheme
        if cfg.mode in ("sync", "stale") and scheme != "repli":
            log.info("%s mode requires halo replicas — forcing "
                     "scheme=repli (was %s)", cfg.mode, scheme)
            scheme = "repli"
        timings: Dict[str, float] = {}
        with _stage_span(timings, "total", "pipeline.total",
                         dataset=cfg.dataset, mode=cfg.mode, k=cfg.k):
            fields = self._run_stages(ds, spec, scheme, timings)
        obs.sample_memory_now()
        fields["timings"] = {k: round(v, 4) for k, v in timings.items()}
        return PipelineReport(**fields)

    # ------------------------------------------------------------------
    def _run_stages(self, ds: Optional[NodeDataset], spec: PartitionerSpec,
                    scheme: str, timings: Dict[str, float]) -> Dict[str, Any]:
        import jax
        cfg = self.config

        # -- stage 1: dataset ------------------------------------------
        with _stage_span(timings, "dataset", "pipeline.dataset",
                         dataset=cfg.dataset):
            if ds is None:
                ds = get_dataset(cfg.dataset, **dict(cfg.dataset_kwargs))
        obs.sample_memory_now()

        # -- stage 2: partition + assembly (load-or-compute) -----------
        need_halo = cfg.mode in ("sync", "stale")
        with _stage_span(timings, "partition_stage", "pipeline.partition",
                         method=spec.canonical(), k=cfg.k,
                         scheme=scheme) as psp:
            if self.store is not None:
                bundle = self.store.load_or_compute(
                    ds.graph, spec, cfg.k, cfg.seed, scheme,
                    with_halo=need_halo)
            else:
                bundle = compute_bundle(ds.graph, spec, cfg.k, cfg.seed,
                                        scheme, with_halo=need_halo)
            timings["partition"] = bundle.partition_seconds
            timings["assemble"] = bundle.assemble_seconds
            psp.set(cache_hit=bundle.labels_hit)
            with obs.span("pipeline.partition_eval"):
                part_report = evaluate_partition(
                    ds.graph, bundle.labels).as_dict()
        obs.sample_memory_now()

        # -- stage 3: per-partition GNN training -----------------------
        with _stage_span(timings, "train", "pipeline.train", mode=cfg.mode,
                         epochs=cfg.epochs, model=cfg.model, k=cfg.k):
            gnn_cfg = GNNConfig(kind=cfg.model,
                                feature_dim=int(ds.features.shape[1]),
                                hidden_dim=cfg.hidden_dim,
                                embed_dim=cfg.embed_dim,
                                num_layers=cfg.num_layers,
                                dropout=cfg.dropout,
                                use_kernel=cfg.use_kernel)
            # kernel config resolution/tuning: one bucket per distinct layer
            # input width at this run's padded partition shape (DESIGN.md §14)
            kernel_info: Optional[Dict[str, Any]] = None
            if cfg.use_kernel:
                from repro.kernels.autotune import autotune as tune_bucket
                from repro.kernels.autotune import get_config
                n_pad, e_pad = bundle.batch.n_pad, bundle.batch.e_pad
                widths = sorted({gnn_cfg.feature_dim, gnn_cfg.hidden_dim})
                if cfg.kernel_autotune:
                    with _stage_span(timings, "kernel_autotune",
                                     "pipeline.kernel_autotune",
                                     widths=widths):
                        for width in widths:
                            chosen, measured = tune_bucket(n_pad, e_pad,
                                                           width)
                            log.info("kernel autotune f=%d -> %s "
                                     "(%d candidates)", width, chosen,
                                     len(measured))
                kernel_info = {
                    f"f{width}": get_config(n_pad, e_pad, width).as_dict()
                    for width in widths}
            mesh = self._resolve_mesh(bundle.batch.k)
            low_memory = cfg.low_memory and cfg.mode == "local"
            if low_memory:
                mesh = None       # sequential path is inherently unsharded
            hlo_out: Optional[Dict[str, str]] = (
                {} if cfg.collect_hlo and not low_memory else None)
            with obs.profiler_session(cfg.jax_profile_dir):
                if cfg.mode == "local":
                    params, embeddings = train_local(
                        ds, bundle.batch, gnn_cfg, epochs=cfg.epochs,
                        lr=cfg.lr, seed=cfg.seed, mesh=mesh,
                        hlo_out=hlo_out, integrate=cfg.integrate,
                        sequential=low_memory)
                elif cfg.mode == "sync":
                    params, embeddings = train_sync(
                        ds, bundle.batch, bundle.halo, gnn_cfg, mesh,
                        epochs=cfg.epochs, lr=cfg.lr, seed=cfg.seed,
                        hlo_out=hlo_out, integrate=cfg.integrate)
                else:
                    params, embeddings = train_stale(
                        ds, bundle.batch, bundle.halo, gnn_cfg, mesh,
                        epochs=cfg.epochs, lr=cfg.lr, seed=cfg.seed,
                        sync_period=cfg.sync_period, hlo_out=hlo_out,
                        integrate=cfg.integrate)
        obs.sample_memory_now()

        collectives: Dict[str, int] = {}
        if hlo_out:
            from repro.launch.hlo_analysis import collective_bytes
            collectives = collective_bytes(hlo_out["hlo"])
            # per-epoch average: what one training epoch actually moves.
            # local: 0; sync: every epoch is an exchange; stale: only every
            # sync_period-th epoch moves the exchange-step bytes.
            if cfg.mode == "stale":
                per_epoch = stale_bytes_per_epoch(
                    collectives["total"], cfg.epochs, cfg.sync_period)
                stale_hlo = hlo_out.get("hlo_stale")
                collectives["stale_step_total"] = (
                    collective_bytes(stale_hlo)["total"] if stale_hlo else 0)
                collectives["n_exchange_epochs"] = len(
                    stale_exchange_epochs(cfg.epochs, cfg.sync_period))
                collectives["per_epoch_avg"] = int(round(
                    sum(per_epoch) / max(cfg.epochs, 1)))
            else:
                collectives["per_epoch_avg"] = collectives["total"]
            # reconcile the HLO byte count with the registry: gauges carry
            # the same numbers the report does, so a trace is self-contained
            obs.gauge("train.collective_bytes_per_step").set(
                collectives["total"])
            obs.gauge("train.collective_bytes_per_epoch_avg").set(
                collectives["per_epoch_avg"])
            log.info("train-step collectives: %d bytes/step, %d bytes/epoch "
                     "avg (mode=%s)", collectives["total"],
                     collectives["per_epoch_avg"], cfg.mode)

        # -- stage 4: classifier on assembled embeddings ---------------
        accuracy: Dict[str, float] = {}
        classifier_params = None
        if cfg.classifier_epochs > 0:
            with _stage_span(timings, "classifier", "pipeline.classifier",
                             epochs=cfg.classifier_epochs):
                accuracy, classifier_params = train_classifier(
                    ds, embeddings, hidden=cfg.classifier_hidden,
                    epochs=cfg.classifier_epochs, seed=cfg.seed,
                    return_params=True)

        # -- stage 5: optional checkpoint ------------------------------
        checkpoint_path = None
        if cfg.checkpoint_dir:
            from repro.checkpoint import save_checkpoint
            checkpoint_path = save_checkpoint(cfg.checkpoint_dir,
                                              cfg.epochs, params)
            log.info("saved model checkpoint: %s", checkpoint_path)

        # -- stage 6: serving bundle export (DESIGN.md §13) ------------
        serving_path = None
        if cfg.serving_dir:
            # lazy import: repro.serving imports repro.gnn/pipeline pieces
            from repro.serving.store import export_from_pipeline
            with _stage_span(timings, "serving_export",
                             "pipeline.serving_export"):
                serving_path = export_from_pipeline(
                    cfg.serving_dir, ds=ds, bundle=bundle, params=params,
                    classifier=classifier_params, embeddings=embeddings)
            log.info("exported serving bundle: %s", serving_path)

        src_once = ds.graph.num_arcs // 2
        return dict(
            config={**dataclasses.asdict(cfg), "scheme": scheme,
                    "method": spec.canonical(),
                    "dataset_kwargs": dict(cfg.dataset_kwargs)},
            dataset=ds.name,
            num_nodes=int(ds.graph.n),
            num_edges=int(src_once),
            num_devices=len(jax.devices()),
            partition=part_report,
            partition_cache_hit=bundle.labels_hit,
            batch_cache_hit=bundle.batch_hit,
            artifact_paths={"labels": bundle.labels_path,
                            "batch": bundle.batch_path},
            shapes={"k": bundle.batch.k, "n_pad": bundle.batch.n_pad,
                    "e_pad": bundle.batch.e_pad},
            collectives=collectives,
            accuracy={k: float(v) for k, v in accuracy.items()},
            checkpoint_path=checkpoint_path,
            partition_fingerprint=bundle.fingerprint or spec.fingerprint(),
            serving_path=serving_path,
            kernel=kernel_info,
        )
