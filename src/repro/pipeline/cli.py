"""CLI for the end-to-end pipeline.

    PYTHONPATH=src python -m repro.pipeline run \
        --dataset karate --method "lpa+f(alpha=0.1)" --k 4 --mode local

    PYTHONPATH=src python -m repro.pipeline partitioners
    PYTHONPATH=src python -m repro.pipeline cache --list
    PYTHONPATH=src python -m repro.pipeline cache --clear

``--method`` accepts any Partitioner API v2 spec string (DESIGN.md §9):
``method``, ``method(field=value,...)``, optionally followed by the ``+f``
fusion combinator — ``"metis"``, ``"lpa(max_iter=30)+f(alpha=0.1)"``,
``"leiden_fusion(resolution=0.5)"``. ``partitioners`` lists the registry
with each method's config schema, defaults, and capability flags.

Partition artifacts land under ``--cache-dir`` (default
``~/.cache/repro/partitions``); a second run with the same dataset/spec/
k/seed logs a cache hit and skips re-partitioning. The key includes the
spec's config fingerprint, so changing any hyperparameter is a cache miss.
"""
from __future__ import annotations

import argparse
import logging
import os
import sys
from typing import List, Optional

DEFAULT_CACHE = os.path.join("~", ".cache", "repro", "partitions")


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.pipeline",
        description="Leiden-Fusion end-to-end pipeline: partition -> "
                    "communication-free GNN training -> embedding assembly "
                    "-> node classification.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    run = sub.add_parser("run", help="run the full pipeline once")
    run.add_argument("--dataset", default="arxiv-like",
                     help="karate | arxiv-like | proteins(-like) | "
                          "arxiv-like-stream (out-of-core: generation "
                          "streams to a chunked mmap CSR bundle on disk, "
                          "DESIGN.md §15)")
    run.add_argument("--nodes", type=int, default=None,
                     help="node count override for synthetic datasets")
    run.add_argument("--dataset-scale", type=float, default=None,
                     help="node-count multiplier for synthetic datasets "
                          "(e.g. 12.5 on arxiv-like -> 500k nodes; the "
                          "vectorized engine partitions it in seconds; "
                          "works for proteins(-like) and the streamed "
                          "variants too)")
    run.add_argument("--dataset-dir", default=None,
                     help="bundle directory for streamed datasets "
                          "(arxiv-like-stream); defaults to a deterministic "
                          "path under the system temp dir")
    run.add_argument("--method", default="leiden_fusion",
                     help="partitioner spec, e.g. leiden_fusion | metis | "
                          "\"lpa+f(alpha=0.1)\" | "
                          "\"leiden_fusion(resolution=0.5)\" — see the "
                          "'partitioners' subcommand for the registry")
    run.add_argument("--k", type=int, default=8)
    run.add_argument("--seed", type=int, default=0)
    run.add_argument("--scheme", default="repli", choices=["inner", "repli"])
    run.add_argument("--mode", default="local",
                     choices=["local", "sync", "stale"],
                     help="local = zero communication (the paper); sync = "
                          "halo exchange every step; stale = exchange every "
                          "--sync-period epochs, frozen halos in between "
                          "(DESIGN.md §12)")
    run.add_argument("--sync-period", type=int, default=4,
                     help="stale mode: halo-exchange period in epochs "
                          "(1 ≡ sync, 0 = never exchange ≡ local)")
    run.add_argument("--integrate", default="none",
                     choices=["none", "model_avg", "ensemble"],
                     help="aggregate the k per-partition models before "
                          "embedding assembly: model_avg parameter-averages "
                          "(arxiv 2305.09887), ensemble averages embeddings")
    run.add_argument("--model", default="gcn", choices=["gcn", "sage"])
    run.add_argument("--use-kernel", action="store_true",
                     help="route GNN layers through the autotuned kernel "
                          "dispatcher (fused Pallas layer on TPU, XLA "
                          "strategy on interpret-mode backends — "
                          "DESIGN.md §3/§11/§14)")
    run.add_argument("--kernel-autotune", action="store_true",
                     help="sweep the kernel tile/strategy search space for "
                          "this run's shape buckets before training and "
                          "cache the winners on disk (DESIGN.md §14; "
                          "no-op without --use-kernel)")
    run.add_argument("--hidden-dim", type=int, default=128)
    run.add_argument("--embed-dim", type=int, default=128)
    run.add_argument("--num-layers", type=int, default=3)
    run.add_argument("--dropout", type=float, default=0.3)
    run.add_argument("--epochs", type=int, default=60)
    run.add_argument("--lr", type=float, default=5e-3)
    run.add_argument("--classifier-epochs", type=int, default=150)
    run.add_argument("--cache-dir", default=DEFAULT_CACHE)
    run.add_argument("--no-cache", action="store_true",
                     help="disable the partition artifact cache")
    run.add_argument("--checkpoint-dir", default=None,
                     help="save trained per-partition params here")
    run.add_argument("--serving-dir", default=None,
                     help="export a repro.serving bundle here (embeddings + "
                          "per-partition heads + classifier + offline "
                          "answer key; requires --classifier-epochs > 0)")
    run.add_argument("--no-hlo", action="store_true",
                     help="skip lowering the train step for the "
                          "collective-bytes report (saves one compile)")
    run.add_argument("--low-memory", action="store_true",
                     help="local mode: train partitions one at a time "
                          "(same math, ~1/k the transient RAM; implies "
                          "unsharded + --no-hlo — DESIGN.md §15)")
    run.add_argument("--trace", default=None, metavar="PATH",
                     help="enable repro.obs tracing and export a Chrome "
                          "trace-event JSON here after the run (open in "
                          "Perfetto; aggregate with 'python -m repro.obs "
                          "summarize PATH' — DESIGN.md §16)")
    run.add_argument("--jax-profile", default=None, metavar="DIR",
                     help="start a jax.profiler session around the "
                          "training stage, writing to DIR")
    run.add_argument("--json", action="store_true",
                     help="print the report as JSON instead of the summary")

    cache = sub.add_parser("cache", help="inspect/clear the artifact cache")
    cache.add_argument("--cache-dir", default=DEFAULT_CACHE)
    cache.add_argument("--list", action="store_true", default=True)
    cache.add_argument("--clear", action="store_true")

    part = sub.add_parser(
        "partitioners",
        help="list registered partitioners with config schemas and "
             "capability flags")
    part.add_argument("--json", action="store_true",
                      help="machine-readable schema dump")
    return ap


def _cmd_run(args: argparse.Namespace) -> int:
    from repro import obs

    from .pipeline import Pipeline, PipelineConfig
    if args.trace:
        obs.enable()
    dataset_kwargs = {}
    if args.nodes is not None:
        dataset_kwargs["n"] = args.nodes
    if args.dataset_scale is not None:
        dataset_kwargs["scale"] = args.dataset_scale
    if args.dataset_dir is not None:
        dataset_kwargs["out_dir"] = args.dataset_dir
    cfg = PipelineConfig(
        dataset=args.dataset, method=args.method, k=args.k, seed=args.seed,
        scheme=args.scheme, mode=args.mode, sync_period=args.sync_period,
        integrate=args.integrate, model=args.model,
        use_kernel=args.use_kernel,
        kernel_autotune=args.kernel_autotune,
        hidden_dim=args.hidden_dim, embed_dim=args.embed_dim,
        num_layers=args.num_layers, dropout=args.dropout,
        epochs=args.epochs, lr=args.lr,
        classifier_epochs=args.classifier_epochs,
        cache_dir=None if args.no_cache else args.cache_dir,
        checkpoint_dir=args.checkpoint_dir,
        serving_dir=args.serving_dir,
        collect_hlo=not args.no_hlo,
        low_memory=args.low_memory,
        jax_profile_dir=args.jax_profile,
        dataset_kwargs=dataset_kwargs)
    report = Pipeline(cfg).run()
    if args.trace:
        path = obs.export_trace(args.trace)
        print(f"trace written: {path} "
              f"({obs.tracer().event_count()} spans) — summarize with "
              f"'python -m repro.obs summarize {path}'", file=sys.stderr)
    if args.json:
        import json
        print(json.dumps(report.as_dict(), indent=2))
    else:
        print(report.summary())
    return 0


def _cmd_cache(args: argparse.Namespace) -> int:
    from .artifacts import PartitionArtifactStore
    store = PartitionArtifactStore(args.cache_dir)
    if args.clear:
        n = store.clear()
        print(f"removed {n} artifact(s) from {store.cache_dir}")
        return 0
    entries = store.entries()
    if not entries:
        print(f"cache empty: {store.cache_dir}")
        return 0
    total = 0
    for name, size in entries:
        total += size
        print(f"{size:>12d}  {name}")
    print(f"{total:>12d}  total ({len(entries)} artifacts) "
          f"in {store.cache_dir}")
    return 0


def _config_schema(config_type) -> dict:
    import dataclasses
    out = {}
    for f in dataclasses.fields(config_type):
        default = f.default if f.default is not dataclasses.MISSING else None
        hint = f.metadata.get("help", "")
        type_name = getattr(f.type, "__name__", str(f.type))
        out[f.name] = {"type": type_name, "default": default, "help": hint}
    return out


def _cmd_partitioners(args: argparse.Namespace) -> int:
    import dataclasses
    from repro.core import FusionConfig, registered_partitioners
    entries = registered_partitioners()
    if args.json:
        import json
        payload = {
            name: {
                "capabilities": dataclasses.asdict(e.capabilities),
                "config": e.config_type.__name__,
                "fields": _config_schema(e.config_type),
                "doc": e.doc,
            } for name, e in entries.items()}
        payload["+f"] = {
            "doc": "fusion combinator over any base method (paper §5.4)",
            "config": FusionConfig.__name__,
            "fields": _config_schema(FusionConfig)}
        print(json.dumps(payload, indent=2))
        return 0
    for name, e in entries.items():
        print(f"{name:16s} [{e.capabilities.describe()}]  {e.doc}")
        schema = _config_schema(e.config_type)
        if not schema:
            print(f"{'':16s}   (no config fields)")
        for field, info in schema.items():
            hint = f"  — {info['help']}" if info["help"] else ""
            print(f"{'':16s}   {field}: {info['type']} = "
                  f"{info['default']!r}{hint}")
    print()
    print("+f               fusion combinator: any spec may end in "
          "\"+f(...)\" (paper §5.4)")
    for field, info in _config_schema(FusionConfig).items():
        hint = f"  — {info['help']}" if info["help"] else ""
        print(f"{'':16s}   {field}: {info['type']} = "
              f"{info['default']!r}{hint}")
    print()
    print("spec grammar: method | method(field=value,...) | base+f | "
          "base(...)+f(field=value,...)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    logging.basicConfig(level=logging.INFO,
                        format="%(levelname)s %(name)s: %(message)s")
    args = _build_parser().parse_args(argv)
    if args.cmd == "run":
        return _cmd_run(args)
    if args.cmd == "partitioners":
        return _cmd_partitioners(args)
    return _cmd_cache(args)


if __name__ == "__main__":
    sys.exit(main())
