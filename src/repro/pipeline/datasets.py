"""Dataset registry for the end-to-end pipeline.

Every entry maps a CLI-friendly name to a factory returning a
:class:`repro.core.NodeDataset`. Names are normalized (``-`` == ``_``), so
``arxiv-like`` and ``arxiv_like`` resolve to the same dataset.

``arxiv_like_stream`` is the out-of-core twin of ``arxiv_like``
(DESIGN.md §15): the same rng draws in the same order, but edges stream
straight into a chunked :class:`~repro.core.MmapGraphStore` bundle and
features into an on-disk ``.npy`` memmap — the full edge list and feature
matrix never exist in RAM, so million-node graphs generate under a
node-sized RAM budget. The resulting CSR is byte-identical to the in-RAM
build at any scale.

Also home of :func:`graph_fingerprint` — the content hash of a graph's CSR
buffers that keys the partition artifact cache (DESIGN.md §1). Partitioning
depends only on topology, so features/labels are deliberately excluded from
the fingerprint: regenerating features does not invalidate cached partitions.
"""
from __future__ import annotations

import hashlib
import os
import tempfile
from typing import Callable, Dict, Optional

import numpy as np

from repro.core import (Graph, NodeDataset, build_store_from_edge_batches,
                        karate_club, make_arxiv_like, make_proteins_like)
from repro.core.graphstore import DEFAULT_CHUNK_ARCS

__all__ = ["DATASETS", "get_dataset", "make_karate_dataset",
           "make_arxiv_like_stream", "graph_fingerprint"]


# Zachary (1977) ground-truth factions: 0 = Mr. Hi, 1 = Officer.
_KARATE_OFFICER = frozenset(
    {9, 14, 15, 18, 20, 22, 23, 24, 25, 26, 27, 28, 29, 30, 31, 32, 33})


def make_karate_dataset(seed: int = 0) -> NodeDataset:
    """Zachary's karate club as a 2-class node-classification task.

    Identity (one-hot) features — the standard featureless-graph setup —
    so the GNN has to learn everything from structure. Small enough that
    the full pipeline runs in seconds; used by the CLI smoke test.
    """
    g = karate_club()
    labels = np.array([1 if v in _KARATE_OFFICER else 0 for v in range(g.n)],
                      dtype=np.int64)
    features = np.eye(g.n, dtype=np.float32)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.n)
    tr, va = int(0.6 * g.n), int(0.8 * g.n)
    train_mask = np.zeros(g.n, bool); train_mask[perm[:tr]] = True
    val_mask = np.zeros(g.n, bool); val_mask[perm[tr:va]] = True
    test_mask = np.zeros(g.n, bool); test_mask[perm[va:]] = True
    return NodeDataset(g, features, labels, 2, train_mask, val_mask,
                       test_mask, multilabel=False, name="karate")


def make_arxiv_like_stream(out_dir: Optional[str] = None, n: int = 40_000,
                           num_classes: int = 40, feature_dim: int = 128,
                           avg_deg: float = 13.8, noise: float = 4.0,
                           seed: int = 0, scale: float = 1.0,
                           chunk_arcs: int = DEFAULT_CHUNK_ARCS
                           ) -> NodeDataset:
    """Out-of-core ``make_arxiv_like``: stream generation to disk.

    Mirrors the in-RAM factory's rng consumption exactly — block sizes,
    per-block SBM edge draws (yielded batch-by-batch into
    :func:`~repro.core.build_store_from_edge_batches`), the
    ``_ensure_connected`` chain draws (via ``connect_rng``), then features
    drawn row-chunk by row-chunk into a ``(n, feature_dim)`` float32 memmap.
    Numpy's Generator fills sample buffers sequentially, so the chunked
    draws reproduce the one-shot draws bit-for-bit: the streamed dataset is
    CSR- and feature-identical to ``make_arxiv_like`` with the same
    arguments, and shares its partition-cache entries
    (:func:`graph_fingerprint` hashes content, not backend).

    Peak RAM is O(n) (indptr, labels, masks, one arc chunk) — the arc-sized
    arrays live in ``out_dir/graph`` (a chunked mmap CSR bundle) and
    features in ``out_dir/features.npy``.
    """
    n = max(int(n * scale), 1)
    if out_dir is None:
        out_dir = os.path.join(tempfile.gettempdir(), "repro-streamed",
                               f"arxiv_like-n{n}-seed{seed}")
    os.makedirs(out_dir, exist_ok=True)
    rng = np.random.default_rng(seed)
    num_blocks = num_classes * 4
    sizes = rng.pareto(1.5, num_blocks) + 1.0
    sizes = np.maximum((sizes / sizes.sum() * n).astype(np.int64), 8)
    block_of = np.repeat(np.arange(num_blocks), sizes)[:n]
    if block_of.shape[0] < n:
        block_of = np.concatenate(
            [block_of, rng.integers(0, num_blocks, n - block_of.shape[0])])
    rng.shuffle(block_of)
    avg_deg_in, avg_deg_out = avg_deg * 0.8, avg_deg * 0.2

    def edge_batches():
        # _sbm_edges, one block per batch — same rng calls in the same order
        for b in range(num_blocks):
            members = np.where(block_of == b)[0]
            nb = members.shape[0]
            if nb < 2:
                continue
            m_in = int(avg_deg_in * nb / 2)
            yield (members[rng.integers(0, nb, m_in)],
                   members[rng.integers(0, nb, m_in)])
        m_out = int(avg_deg_out * n / 2)
        yield rng.integers(0, n, m_out), rng.integers(0, n, m_out)

    g = build_store_from_edge_batches(
        os.path.join(out_dir, "graph"), n, edge_batches(),
        est_arcs=int(avg_deg * n) + 16, chunk_arcs=chunk_arcs,
        ensure_connected=True, connect_rng=rng)
    labels = (block_of % num_classes).astype(np.int64)
    centers = rng.normal(0, 1, (num_blocks, feature_dim))
    feats = np.lib.format.open_memmap(
        os.path.join(out_dir, "features.npy"), mode="w+",
        dtype=np.float32, shape=(n, feature_dim))
    step = max(4_000_000 // max(feature_dim, 1), 1)
    for r0 in range(0, n, step):
        r1 = min(r0 + step, n)
        feats[r0:r1] = (centers[block_of[r0:r1]]
                        + rng.normal(0, noise, (r1 - r0, feature_dim))
                        ).astype(np.float32)
    feats.flush()
    perm = rng.permutation(n)
    tr, va = int(0.6 * n), int(0.8 * n)
    train_mask = np.zeros(n, bool); train_mask[perm[:tr]] = True
    val_mask = np.zeros(n, bool); val_mask[perm[tr:va]] = True
    test_mask = np.zeros(n, bool); test_mask[perm[va:]] = True
    return NodeDataset(g, feats, labels, num_classes, train_mask, val_mask,
                       test_mask, multilabel=False, name="arxiv_like_stream")


DATASETS: Dict[str, Callable[..., NodeDataset]] = {
    "karate": make_karate_dataset,
    "arxiv_like": make_arxiv_like,
    "arxiv_like_stream": make_arxiv_like_stream,
    "proteins_like": make_proteins_like,
    # short aliases, CLI convenience
    "arxiv": make_arxiv_like,
    "proteins": make_proteins_like,
}


def get_dataset(name: str, **kwargs) -> NodeDataset:
    """Resolve ``name`` (hyphens/underscores interchangeable) and build it."""
    key = name.replace("-", "_")
    try:
        factory = DATASETS[key]
    except KeyError:
        raise KeyError(f"unknown dataset {name!r}; "
                       f"available: {sorted(DATASETS)}") from None
    return factory(**kwargs)


def graph_fingerprint(g: Graph) -> str:
    """Content hash of the graph topology (hex sha256).

    Hashes the CSR buffers + node/self weights; two graphs with identical
    structure produce identical partition artifacts, so they share cache
    entries regardless of how they were constructed. An out-of-core
    :class:`~repro.core.MmapGraphStore` is hashed by streaming the same
    logical arrays chunk-by-chunk in the same order/dtype, so a store and
    the in-RAM ``Graph`` with identical CSR share cache entries too.
    """
    h = hashlib.sha256()
    h.update(np.int64(g.n).tobytes())
    # Canonicalize the two equivalent "no self-loops" spellings (zeros(0)
    # vs zeros(n)) so backends that differ only in that convention hash
    # identically.
    sw = np.asarray(g.self_weight, dtype=np.float64)
    if not sw.any():
        sw = np.zeros(0)
    if getattr(g, "out_of_core", False):
        def logical(dtype: str, parts) -> None:
            h.update(np.dtype(dtype).str.encode())
            for a in parts:
                h.update(np.ascontiguousarray(a).tobytes())
        logical("int64", (np.asarray(g.indptr, dtype=np.int64),))
        logical("int32", (ch.dst.astype(np.int32)
                          for ch in g.iter_csr_chunks()))
        logical("float64", (np.asarray(ch.weight, dtype=np.float64)
                            for ch in g.iter_csr_chunks()))
        logical("float64", (np.asarray(g.node_weight, dtype=np.float64),))
        logical("float64", (sw,))
        return h.hexdigest()
    for arr in (g.indptr, g.indices, g.edge_weight, g.node_weight, sw):
        a = np.ascontiguousarray(arr)
        h.update(a.dtype.str.encode())
        h.update(a.tobytes())
    return h.hexdigest()
