"""Dataset registry for the end-to-end pipeline.

Every entry maps a CLI-friendly name to a factory returning a
:class:`repro.core.NodeDataset`. Names are normalized (``-`` == ``_``), so
``arxiv-like`` and ``arxiv_like`` resolve to the same dataset.

Also home of :func:`graph_fingerprint` — the content hash of a graph's CSR
buffers that keys the partition artifact cache (DESIGN.md §1). Partitioning
depends only on topology, so features/labels are deliberately excluded from
the fingerprint: regenerating features does not invalidate cached partitions.
"""
from __future__ import annotations

import hashlib
from typing import Callable, Dict

import numpy as np

from repro.core import (Graph, NodeDataset, karate_club, make_arxiv_like,
                        make_proteins_like)

__all__ = ["DATASETS", "get_dataset", "make_karate_dataset",
           "graph_fingerprint"]


# Zachary (1977) ground-truth factions: 0 = Mr. Hi, 1 = Officer.
_KARATE_OFFICER = frozenset(
    {9, 14, 15, 18, 20, 22, 23, 24, 25, 26, 27, 28, 29, 30, 31, 32, 33})


def make_karate_dataset(seed: int = 0) -> NodeDataset:
    """Zachary's karate club as a 2-class node-classification task.

    Identity (one-hot) features — the standard featureless-graph setup —
    so the GNN has to learn everything from structure. Small enough that
    the full pipeline runs in seconds; used by the CLI smoke test.
    """
    g = karate_club()
    labels = np.array([1 if v in _KARATE_OFFICER else 0 for v in range(g.n)],
                      dtype=np.int64)
    features = np.eye(g.n, dtype=np.float32)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.n)
    tr, va = int(0.6 * g.n), int(0.8 * g.n)
    train_mask = np.zeros(g.n, bool); train_mask[perm[:tr]] = True
    val_mask = np.zeros(g.n, bool); val_mask[perm[tr:va]] = True
    test_mask = np.zeros(g.n, bool); test_mask[perm[va:]] = True
    return NodeDataset(g, features, labels, 2, train_mask, val_mask,
                       test_mask, multilabel=False, name="karate")


DATASETS: Dict[str, Callable[..., NodeDataset]] = {
    "karate": make_karate_dataset,
    "arxiv_like": make_arxiv_like,
    "proteins_like": make_proteins_like,
}


def get_dataset(name: str, **kwargs) -> NodeDataset:
    """Resolve ``name`` (hyphens/underscores interchangeable) and build it."""
    key = name.replace("-", "_")
    try:
        factory = DATASETS[key]
    except KeyError:
        raise KeyError(f"unknown dataset {name!r}; "
                       f"available: {sorted(DATASETS)}") from None
    return factory(**kwargs)


def graph_fingerprint(g: Graph) -> str:
    """Content hash of the graph topology (hex sha256).

    Hashes the CSR buffers + node/self weights; two graphs with identical
    structure produce identical partition artifacts, so they share cache
    entries regardless of how they were constructed.
    """
    h = hashlib.sha256()
    h.update(np.int64(g.n).tobytes())
    for arr in (g.indptr, g.indices, g.edge_weight, g.node_weight,
                g.self_weight):
        a = np.ascontiguousarray(arr)
        h.update(a.dtype.str.encode())
        h.update(a.tobytes())
    return h.hexdigest()
