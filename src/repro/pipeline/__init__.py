"""End-to-end pipeline: partition (cached) -> train -> assemble -> eval.

See DESIGN.md §1 for the architecture and the artifact-cache format, and
``python -m repro.pipeline run --help`` for the CLI.
"""
from .artifacts import (ARTIFACT_VERSION, ArtifactBundle,
                        PartitionArtifactStore, compute_bundle)
from .datasets import DATASETS, get_dataset, graph_fingerprint, \
    make_karate_dataset
from .pipeline import Pipeline, PipelineConfig, PipelineReport

__all__ = ["ARTIFACT_VERSION", "ArtifactBundle", "PartitionArtifactStore",
           "compute_bundle", "DATASETS", "get_dataset", "graph_fingerprint",
           "make_karate_dataset", "Pipeline", "PipelineConfig",
           "PipelineReport"]
