"""Partitioning methods: Leiden-Fusion + the paper's baselines.

- ``random_partition``  — uniform node assignment (paper §3.1).
- ``lpa_partition``     — Label Propagation seeded with k labels, as used by
  Spark Local [Duong et al. 2021] and reproduced in the paper.
- ``metis_partition``   — a self-contained multilevel k-way partitioner in
  the METIS family: heavy-edge-matching coarsening, greedy k-way initial
  partition, Fiduccia–Mattheyses-style boundary refinement. (The original
  METIS C library is not available offline; this reproduces its *behavioral
  profile* — low edge cut, balanced sizes, but no connectivity guarantee —
  which is exactly the property the paper contrasts against.)
- ``with_fusion``       — the "+F" operator of paper §5.4: split every
  partition into its connected components, then run community Fusion down
  to k partitions.
- ``leiden_fusion``     — re-exported from :mod:`repro.core.fusion`.

Every method is registered in the Partitioner API v2 registry
(:mod:`repro.core.registry`) with a frozen config dataclass and capability
flags, and is selectable through spec strings (:mod:`repro.core.spec`):
``"lpa(max_iter=30)"``, ``"metis+f(alpha=0.1)"``,
``"leiden_fusion(resolution=0.5)"``. The old ``PARTITIONERS`` dict and
``get_partitioner`` remain as deprecation shims.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Iterator, Mapping, Optional

import numpy as np

from .engine import split_components as engine_split_components
from .fusion import fuse, leiden_fusion
from .graph import Graph
from .registry import (Capabilities, FusionConfig, NullConfig,
                       register_partitioner)

__all__ = ["random_partition", "single_partition", "lpa_partition",
           "metis_partition", "leiden_fusion", "with_fusion",
           "split_into_components",
           "SingleConfig", "RandomConfig", "LpaConfig", "MetisConfig",
           "LeidenFusionConfig",
           "get_partitioner", "PARTITIONERS"]


def random_partition(g: Graph, k: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, k, g.n).astype(np.int64)


def single_partition(g: Graph, k: int = 1, seed: int = 0) -> np.ndarray:
    """Everything in one partition — the centralized reference (k ignored)."""
    return np.zeros(g.n, dtype=np.int64)


def lpa_partition(g: Graph, k: int, seed: int = 0, max_iter: int = 50,
                  balance_cap: float = 1.10) -> np.ndarray:
    """Label propagation with k initial labels (partitioning variant).

    Nodes start with a random label in [0, k); each sweep assigns every node
    the (weighted) majority label of its neighbors, subject to a soft size
    cap so partitions stay usable (Spinner-style). Sensitive to the seed by
    construction — the paper calls this out as LPA's weakness.
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, k, g.n).astype(np.int64)
    cap = balance_cap * g.n / k
    counts = np.bincount(labels, minlength=k).astype(np.float64)
    indptr, indices, ew = g.indptr, g.indices, g.edge_weight
    for _ in range(max_iter):
        moved = 0
        order = rng.permutation(g.n)
        for v in order:
            v = int(v)
            nbrs = indices[indptr[v]:indptr[v + 1]]
            if nbrs.size == 0:
                continue
            w = ew[indptr[v]:indptr[v + 1]]
            score = np.zeros(k)
            np.add.at(score, labels[nbrs], w)
            # soft cap: forbid overfull targets
            cur = int(labels[v])
            score[(counts >= cap)] = -np.inf
            score[cur] = max(score[cur], 0.0) if counts[cur] < cap else score[cur]
            new = int(np.argmax(score))
            if score[new] == -np.inf:
                new = cur
            if new != cur and score[new] >= score[cur]:
                labels[v] = new
                counts[cur] -= 1
                counts[new] += 1
                moved += 1
        if moved == 0:
            break
    return labels


# ---------------------------------------------------------------------------
# METIS-like multilevel k-way partitioner
# ---------------------------------------------------------------------------

def _heavy_edge_matching(g: Graph, rng: np.random.Generator) -> np.ndarray:
    """Greedy heavy-edge matching; returns coarse node id per node."""
    match = np.full(g.n, -1, dtype=np.int64)
    order = rng.permutation(g.n)
    for v in order:
        v = int(v)
        if match[v] >= 0:
            continue
        nbrs = g.indices[g.indptr[v]:g.indptr[v + 1]]
        ws = g.edge_weight[g.indptr[v]:g.indptr[v + 1]]
        best, best_w = -1, -1.0
        for u, w in zip(nbrs, ws):
            u = int(u)
            if match[u] < 0 and u != v and w > best_w:
                best, best_w = u, w
        if best >= 0:
            match[v] = v
            match[best] = v
        else:
            match[v] = v
    # compact coarse ids
    _, coarse = np.unique(match, return_inverse=True)
    return coarse.astype(np.int64)


def _bfs_order(g: Graph, nodes: np.ndarray, rng: np.random.Generator
               ) -> np.ndarray:
    """BFS ordering of ``nodes`` within their induced subgraph (all
    components, restarting from an arbitrary unvisited node)."""
    inset = np.zeros(g.n, dtype=bool)
    inset[nodes] = True
    seen = np.zeros(g.n, dtype=bool)
    order: list[int] = []
    for seed in rng.permutation(nodes):
        seed = int(seed)
        if seen[seed]:
            continue
        seen[seed] = True
        queue = [seed]
        head = 0
        while head < len(queue):
            v = queue[head]; head += 1
            order.append(v)
            for u in g.neighbors(v):
                u = int(u)
                if inset[u] and not seen[u]:
                    seen[u] = True
                    queue.append(u)
    return np.array(order, dtype=np.int64)


def _greedy_growth_partition(g: Graph, k: int, rng: np.random.Generator
                             ) -> np.ndarray:
    """Initial k-way partition by recursive BFS bisection (balanced by
    node weight; BFS prefixes keep the halves mostly contiguous)."""
    labels = np.zeros(g.n, dtype=np.int64)

    def split(nodes: np.ndarray, parts: int, base: int) -> None:
        if parts == 1:
            labels[nodes] = base
            return
        left_parts = parts // 2
        order = _bfs_order(g, nodes, rng)
        w = np.cumsum(g.node_weight[order])
        target = w[-1] * left_parts / parts
        cut = int(np.searchsorted(w, target)) + 1
        cut = min(max(cut, 1), order.shape[0] - 1)
        split(order[:cut], left_parts, base)
        split(order[cut:], parts - left_parts, base + left_parts)

    split(np.arange(g.n, dtype=np.int64), k, 0)
    return labels


def _fm_refine(g: Graph, labels: np.ndarray, k: int, passes: int = 4,
               balance_cap: float = 1.05) -> np.ndarray:
    """Boundary FM refinement: move boundary nodes to reduce cut, keep balance."""
    labels = labels.copy()
    total = g.node_weight.sum()
    cap = balance_cap * total / k
    sizes = np.zeros(k)
    np.add.at(sizes, labels, g.node_weight)
    indptr, indices, ew = g.indptr, g.indices, g.edge_weight
    for _ in range(passes):
        moved = 0
        for v in range(g.n):
            nbrs = indices[indptr[v]:indptr[v + 1]]
            if nbrs.size == 0:
                continue
            w = ew[indptr[v]:indptr[v + 1]]
            cur = int(labels[v])
            score = np.zeros(k)
            np.add.at(score, labels[nbrs], w)
            gain = score - score[cur]
            gain[cur] = 0.0
            gain[sizes + g.node_weight[v] > cap] = -np.inf
            best = int(np.argmax(gain))
            if gain[best] > 1e-12:
                labels[v] = best
                sizes[cur] -= g.node_weight[v]
                sizes[best] += g.node_weight[v]
                moved += 1
        if moved == 0:
            break
    return labels


def metis_partition(g: Graph, k: int, seed: int = 0,
                    coarsen_to: int = 400) -> np.ndarray:
    """Multilevel k-way partitioning (METIS family)."""
    rng = np.random.default_rng(seed)
    graphs = [g]
    mappings = []  # mappings[i]: nodes of graphs[i] -> nodes of graphs[i+1]
    while graphs[-1].n > max(coarsen_to, 4 * k):
        coarse = _heavy_edge_matching(graphs[-1], rng)
        if int(coarse.max()) + 1 >= graphs[-1].n:  # matching stalled
            break
        mappings.append(coarse)
        graphs.append(graphs[-1].aggregate(coarse))
    labels = _greedy_growth_partition(graphs[-1], k, rng)
    labels = _fm_refine(graphs[-1], labels, k)
    # uncoarsen with refinement at each level
    for level in range(len(mappings) - 1, -1, -1):
        labels = labels[mappings[level]]
        labels = _fm_refine(graphs[level], labels, k)
    return labels.astype(np.int64)


# ---------------------------------------------------------------------------
# "+F" — fusion applied to any base partitioning (paper §5.4)
# ---------------------------------------------------------------------------

def split_into_components(g: Graph, labels: np.ndarray) -> np.ndarray:
    """Relabel so every connected component of every partition is its own
    community (the extra step the paper notes makes +F slower for METIS/LPA).

    One vectorized union-find pass over the intra-partition edges
    (:func:`repro.core.engine.split_components`) instead of a per-partition
    BFS loop.
    """
    return engine_split_components(g, labels)


def with_fusion(base: Callable[..., np.ndarray], g: Graph, k: int,
                alpha: float = 0.05, seed: int = 0,
                base_k: Optional[int] = None) -> np.ndarray:
    """Run ``base`` (with base_k or k target), split into components, fuse to k.

    Functional form of the spec-level ``+f`` combinator
    (``"metis+f(alpha=0.1)"``), kept for direct calls with unregistered
    bases.
    """
    labels = base(g, base_k or k, seed=seed)
    comms = split_into_components(g, labels)
    max_part_size = (g.n / k) * (1.0 + alpha)
    return fuse(g, comms, k, max_part_size)


# ---------------------------------------------------------------------------
# typed configs + registry entries (Partitioner API v2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SingleConfig:
    """The centralized reference has no hyperparameters."""


@dataclasses.dataclass(frozen=True)
class RandomConfig:
    """Uniform random assignment has no hyperparameters."""


@dataclasses.dataclass(frozen=True)
class LpaConfig:
    max_iter: int = dataclasses.field(
        default=50, metadata={"help": "propagation sweeps before giving up"})
    balance_cap: float = dataclasses.field(
        default=1.10, metadata={"help": "soft size cap as a multiple of n/k"})

    def __post_init__(self):
        if self.max_iter < 1:
            raise ValueError(f"max_iter must be >= 1, got {self.max_iter}")
        if self.balance_cap < 1.0:
            raise ValueError(f"balance_cap must be >= 1.0, "
                             f"got {self.balance_cap}")


@dataclasses.dataclass(frozen=True)
class MetisConfig:
    coarsen_to: int = dataclasses.field(
        default=400, metadata={"help": "stop coarsening below this many "
                                       "nodes"})

    def __post_init__(self):
        if self.coarsen_to < 1:
            raise ValueError(f"coarsen_to must be >= 1, "
                             f"got {self.coarsen_to}")


@dataclasses.dataclass(frozen=True)
class LeidenFusionConfig:
    alpha: float = dataclasses.field(
        default=0.05, metadata={"help": "balance slack: max part size is "
                                        "(n/k)*(1+alpha)"})
    beta: float = dataclasses.field(
        default=0.5, metadata={"help": "Leiden community size cap as a "
                                       "fraction of max part size"})
    resolution: float = dataclasses.field(
        default=1.0, metadata={"help": "Leiden modularity resolution gamma"})

    def __post_init__(self):
        if not (self.alpha >= 0.0):
            raise ValueError(f"alpha must be >= 0, got {self.alpha}")
        if not (0.0 < self.beta <= 1.0):
            raise ValueError(f"beta must be in (0, 1], got {self.beta}")
        if not (self.resolution > 0.0):
            raise ValueError(f"resolution must be > 0, "
                             f"got {self.resolution}")


@register_partitioner(
    "single", config=SingleConfig,
    capabilities=Capabilities(connectivity_guaranteed=True, balanced=False),
    doc="everything in one partition — the centralized reference")
def _single(g: Graph, k: int, seed: int, cfg: SingleConfig) -> np.ndarray:
    return single_partition(g, k, seed=seed)


@register_partitioner(
    "random", config=RandomConfig,
    capabilities=Capabilities(connectivity_guaranteed=False, balanced=False),
    doc="uniform random node assignment (paper §3.1 baseline)")
def _random(g: Graph, k: int, seed: int, cfg: RandomConfig) -> np.ndarray:
    return random_partition(g, k, seed=seed)


@register_partitioner(
    "lpa", config=LpaConfig,
    capabilities=Capabilities(connectivity_guaranteed=False, balanced=True),
    doc="label propagation with k initial labels (Spark Local baseline)")
def _lpa(g: Graph, k: int, seed: int, cfg: LpaConfig) -> np.ndarray:
    return lpa_partition(g, k, seed=seed, max_iter=cfg.max_iter,
                         balance_cap=cfg.balance_cap)


@register_partitioner(
    "metis", config=MetisConfig,
    capabilities=Capabilities(connectivity_guaranteed=False, balanced=True),
    doc="multilevel k-way partitioning (METIS family)")
def _metis(g: Graph, k: int, seed: int, cfg: MetisConfig) -> np.ndarray:
    return metis_partition(g, k, seed=seed, coarsen_to=cfg.coarsen_to)


@register_partitioner(
    "leiden_fusion", config=LeidenFusionConfig,
    capabilities=Capabilities(connectivity_guaranteed=True, balanced=True),
    doc="the paper's method: size-capped Leiden + community Fusion")
def _leiden_fusion(g: Graph, k: int, seed: int,
                   cfg: LeidenFusionConfig) -> np.ndarray:
    return leiden_fusion(g, k, alpha=cfg.alpha, beta=cfg.beta, seed=seed,
                         gamma=cfg.resolution)


# ---------------------------------------------------------------------------
# deprecation shims — the closed v1 API, kept for old call-sites
# ---------------------------------------------------------------------------

_LEGACY_NAMES = ("single", "random", "lpa", "metis", "leiden_fusion",
                 "metis_f", "lpa_f")


def _warn_deprecated(what: str) -> None:
    warnings.warn(
        f"{what} is deprecated; use repro.core.partition_from_spec / "
        f"PartitionerSpec.parse (spec strings like \"lpa+f(alpha=0.1)\")",
        DeprecationWarning, stacklevel=3)


def _legacy_callable(name: str) -> Callable[..., np.ndarray]:
    if name not in _LEGACY_NAMES:
        raise KeyError(f"unknown partitioner {name!r}; "
                       f"available: {sorted(_LEGACY_NAMES)}")

    def call(g: Graph, k: int, seed: int = 0, **overrides) -> np.ndarray:
        from .spec import PartitionerSpec
        spec = PartitionerSpec.parse(name)
        if overrides:
            spec = dataclasses.replace(
                spec, config=dataclasses.replace(spec.config, **overrides))
        return spec.partition(g, k, seed=seed).labels

    call.__name__ = f"{name}_partitioner"
    call.__qualname__ = call.__name__
    return call


class _DeprecatedPartitioners(Mapping):
    """v1 ``PARTITIONERS`` dict shim: item access warns and returns a bare
    ``(g, k, seed) -> labels`` callable backed by the v2 registry."""

    def __getitem__(self, name: str) -> Callable[..., np.ndarray]:
        fn = _legacy_callable(name)         # KeyError before the warning
        _warn_deprecated(f"PARTITIONERS[{name!r}]")
        return fn

    def __iter__(self) -> Iterator[str]:
        return iter(_LEGACY_NAMES)

    def __len__(self) -> int:
        return len(_LEGACY_NAMES)

    def __repr__(self) -> str:
        return f"PARTITIONERS({', '.join(_LEGACY_NAMES)})"


PARTITIONERS = _DeprecatedPartitioners()


def get_partitioner(name: str) -> Callable[..., np.ndarray]:
    """Deprecated v1 lookup; use spec strings via
    :func:`repro.core.partition_from_spec` instead."""
    fn = _legacy_callable(name)             # KeyError before the warning
    _warn_deprecated(f"get_partitioner({name!r})")
    return fn
