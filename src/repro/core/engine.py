"""Vectorized partitioning engine — the one CSR-native community state.

Before this module existed the same quotient-graph computation was
re-implemented three times (``Graph.aggregate``, ``fusion.community_cuts``,
``metrics.evaluate_partition``), each as a Python node-at-a-time loop or a
dict-of-dict structure that capped the repo at toy graph sizes. Everything
community-shaped now routes through three primitives here (DESIGN.md §10):

* :func:`quotient_edges` — THE quotient-graph/cut builder: deduped
  inter-community arcs via one ``argsort`` + ``add.reduceat`` pass, plus
  per-community internal weight and node weight. ``Graph.aggregate``,
  ``community_cuts`` and ``evaluate_partition`` are all thin views of it.
* :func:`connected_components` — array union-find (Shiloach–Vishkin style
  min-hooking + pointer jumping), O(m) per round, O(log n) rounds. Replaces
  the per-node BFS in ``Graph.connected_components`` with an implementation
  that produces byte-identical component numbering (components are numbered
  in increasing order of their smallest member node — the same order BFS
  seeds them in).
* :class:`CommunityState` — labels + per-community sizes/degrees + a
  community adjacency held as per-community *sorted arrays* (built once from
  :func:`quotient_edges`, updated incrementally on merge with O(deg) array
  concatenate/sort, stale ids resolved lazily through a union-find). This is
  what drives the greedy Fusion loop (Algorithms 1–2) at array speed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, List, NamedTuple, Optional, Tuple

import numpy as np

from repro import obs

__all__ = ["ArcChunk", "QuotientEdges", "quotient_edges",
           "connected_components", "connected_components_chunks",
           "split_components", "CommunityState"]


class ArcChunk(NamedTuple):
    """One contiguous CSR slab: all arcs of rows [row_start, row_stop).

    The unit of the out-of-core protocol (DESIGN.md §15): both graph
    backends yield these from ``iter_csr_chunks()`` — the in-RAM ``Graph``
    as a single zero-copy chunk covering the whole CSR, ``MmapGraphStore``
    as one chunk per on-disk shard — and every sequential-sweep primitive
    in this module consumes them instead of whole-array ``arcs()``.
    """
    row_start: int
    row_stop: int
    arc_start: int
    arc_stop: int
    src: np.ndarray       # (a,) int64 global row id per arc
    dst: np.ndarray       # (a,) int64
    weight: np.ndarray    # (a,) float64


# ---------------------------------------------------------------------------
# quotient graph / cuts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuotientEdges:
    """Deduped community-level arc arrays for one labelling of a graph.

    ``src``/``dst``/``weight`` hold every *directed* inter-community arc
    exactly once (both directions present, sorted lexicographically by
    ``(src, dst)``), so ``weight[src == a][dst == b].sum()`` is the total
    edge weight cut between communities ``a`` and ``b``. ``intra`` is the
    per-community internal weight in *undirected* terms (member self-loops
    included) and ``node_weight`` the per-community sum of member node
    weights.
    """
    k: int
    src: np.ndarray           # (q,) int64
    dst: np.ndarray           # (q,) int64
    weight: np.ndarray        # (q,) float64
    intra: np.ndarray         # (k,) float64
    node_weight: np.ndarray   # (k,) float64

    def indptr(self) -> np.ndarray:
        """CSR row pointers over ``src`` (valid because src is sorted)."""
        counts = np.bincount(self.src, minlength=self.k)
        out = np.zeros(self.k + 1, dtype=np.int64)
        np.cumsum(counts, out=out[1:])
        return out


def quotient_edges(g, labels: np.ndarray,
                   weights: Optional[np.ndarray] = None,
                   self_weight: Optional[np.ndarray] = None) -> QuotientEdges:
    """The single quotient-graph/cut computation (see module docstring).

    ``weights`` optionally overrides the per-arc weights (e.g. all-ones to
    count edges instead of summing weights); ``self_weight`` likewise
    overrides the per-node self-loop weight folded into ``intra``.
    """
    labels = np.asarray(labels, dtype=np.int64)
    k = int(labels.max()) + 1 if labels.size else 0
    if self_weight is None:
        sw = g.self_weight
        if sw.shape[0] != g.n:     # Graph's zero-length default
            sw = np.zeros(g.n)
    else:
        sw = np.asarray(self_weight, dtype=np.float64)
        if sw.shape[0] != g.n:
            raise ValueError(f"self_weight has shape {sw.shape}, "
                             f"expected ({g.n},)")
    obs.counter("engine.quotient_calls").inc()
    if getattr(g, "out_of_core", False):
        with obs.span("engine.quotient", k=k, n=int(g.n), chunked=True):
            return _quotient_edges_chunked(g, labels, k, weights, sw)
    with obs.span("engine.quotient", k=k, n=int(g.n)):
        return _quotient_edges_in_ram(g, labels, k, weights, sw)


def _quotient_edges_in_ram(g, labels: np.ndarray, k: int,
                           weights: Optional[np.ndarray],
                           sw: np.ndarray) -> QuotientEdges:
    src, dst, w = g.arcs()
    if weights is not None:
        w = np.asarray(weights, dtype=np.float64)
    ls, ld = labels[src], labels[dst]
    inter = ls != ld
    key = ls[inter] * k + ld[inter]
    order = np.argsort(key, kind="stable")
    key = key[order]
    ws = w[inter][order]
    if key.size:
        starts = np.flatnonzero(np.r_[True, key[1:] != key[:-1]])
        qw = np.add.reduceat(ws, starts)
        qk = key[starts]
        qs, qd = qk // k, qk % k
    else:
        qs = qd = np.zeros(0, dtype=np.int64)
        qw = np.zeros(0, dtype=np.float64)
    # intra arcs appear twice (both directions) -> /2 for undirected weight,
    # plus any pre-existing member self-loops.
    intra = np.bincount(ls[~inter], weights=w[~inter], minlength=k) / 2.0
    intra += np.bincount(labels, weights=sw, minlength=k)
    node_w = np.bincount(labels, weights=g.node_weight, minlength=k)
    return QuotientEdges(k=k, src=qs, dst=qd, weight=qw, intra=intra,
                         node_weight=node_w)


def _quotient_edges_chunked(g, labels: np.ndarray, k: int,
                            weights: Optional[np.ndarray],
                            sw: np.ndarray) -> QuotientEdges:
    """The out-of-core body of :func:`quotient_edges`: one sequential sweep
    over ``iter_csr_chunks()``, per-chunk argsort+reduceat partials, then a
    final merge over the (already community-sized) partials. Peak RAM is one
    chunk's arcs plus O(k + total inter-community pairs), never O(num_arcs).
    """
    part_keys: List[np.ndarray] = []
    part_w: List[np.ndarray] = []
    intra = np.zeros(k, dtype=np.float64)
    for ch in g.iter_csr_chunks():
        w = (ch.weight if weights is None else
             np.asarray(weights[ch.arc_start:ch.arc_stop], dtype=np.float64))
        ls, ld = labels[ch.src], labels[ch.dst]
        inter = ls != ld
        if (~inter).any():
            intra += np.bincount(ls[~inter], weights=w[~inter], minlength=k)
        key = ls[inter] * k + ld[inter]
        if key.size:
            order = np.argsort(key, kind="stable")
            key, wi = key[order], w[inter][order]
            starts = np.flatnonzero(np.r_[True, key[1:] != key[:-1]])
            part_keys.append(key[starts])
            part_w.append(np.add.reduceat(wi, starts))
    intra /= 2.0
    intra += np.bincount(labels, weights=sw, minlength=k)
    node_w = np.bincount(labels, weights=g.node_weight, minlength=k)
    if part_keys:
        key = np.concatenate(part_keys)
        pw = np.concatenate(part_w)
        order = np.argsort(key, kind="stable")
        key, pw = key[order], pw[order]
        starts = np.flatnonzero(np.r_[True, key[1:] != key[:-1]])
        qw = np.add.reduceat(pw, starts)
        qk = key[starts]
        qs, qd = qk // k, qk % k
    else:
        qs = qd = np.zeros(0, dtype=np.int64)
        qw = np.zeros(0, dtype=np.float64)
    return QuotientEdges(k=k, src=qs, dst=qd, weight=qw, intra=intra,
                         node_weight=node_w)


# ---------------------------------------------------------------------------
# connected components (array union-find)
# ---------------------------------------------------------------------------

def _pointer_jump(parent: np.ndarray) -> np.ndarray:
    while True:
        jumped = parent[parent]
        if np.array_equal(jumped, parent):
            return parent
        parent = jumped


def connected_components(n: int, src: np.ndarray, dst: np.ndarray,
                         mask: Optional[np.ndarray] = None) -> np.ndarray:
    """Component labels via min-hooking union-find over the given arcs.

    One arc direction suffices (reciprocal arcs are harmless). Components
    are numbered 0..k-1 in increasing order of their smallest member node;
    nodes outside ``mask`` get -1. Every in-mask node with no in-mask arc
    is its own component.
    """
    parent = np.arange(n, dtype=np.int64)
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if mask is not None:
        keep = mask[src] & mask[dst]
        src, dst = src[keep], dst[keep]
    while src.size:
        ps, pd = parent[src], parent[dst]
        hooked = ps != pd
        if not hooked.any():
            break
        hi = np.maximum(ps, pd)[hooked]
        lo = np.minimum(ps, pd)[hooked]
        np.minimum.at(parent, hi, lo)
        parent = _pointer_jump(parent)
    comp = np.full(n, -1, dtype=np.int64)
    m = np.ones(n, dtype=bool) if mask is None else np.asarray(mask, bool)
    if m.any():
        # roots are the min member node of each component, so sorting by
        # root reproduces the BFS seed (= first occurrence) numbering.
        _, ids = np.unique(parent[m], return_inverse=True)
        comp[m] = ids
    return comp


def connected_components_chunks(
        n: int,
        make_chunks: Callable[[], Iterable[Tuple[np.ndarray, np.ndarray]]],
        mask: Optional[np.ndarray] = None) -> np.ndarray:
    """:func:`connected_components` over streamed arc chunks.

    ``make_chunks`` returns a *fresh* iterable of ``(src, dst)`` arc pairs
    each time it is called; the union-find makes repeated passes over it
    (min-hooking + pointer jumping per chunk) until a full pass hooks
    nothing. Peak RAM is O(n) parent state plus one chunk of arcs — this is
    how component structure is computed for graphs whose arc list does not
    fit in RAM. The fixed point (parent = smallest member of the component)
    and therefore the component numbering are identical to the whole-array
    version, which stays untouched for the in-RAM path.
    """
    parent = np.arange(n, dtype=np.int64)
    m = None if mask is None else np.asarray(mask, bool)
    while True:
        changed = False
        for src, dst in make_chunks():
            src = np.asarray(src, dtype=np.int64)
            dst = np.asarray(dst, dtype=np.int64)
            if m is not None:
                keep = m[src] & m[dst]
                src, dst = src[keep], dst[keep]
            if not src.size:
                continue
            ps, pd = parent[src], parent[dst]
            hooked = ps != pd
            if not hooked.any():
                continue
            hi = np.maximum(ps, pd)[hooked]
            lo = np.minimum(ps, pd)[hooked]
            np.minimum.at(parent, hi, lo)
            parent = _pointer_jump(parent)
            changed = True
        if not changed:
            break
    comp = np.full(n, -1, dtype=np.int64)
    mm = np.ones(n, dtype=bool) if m is None else m
    if mm.any():
        _, ids = np.unique(parent[mm], return_inverse=True)
        comp[mm] = ids
    return comp


def split_components(g, labels: np.ndarray) -> np.ndarray:
    """Relabel so every connected component of every community is its own
    community (the "+F" pre-split of paper §5.4), fully vectorized.

    Components of the intra-community edge subgraph *are* the per-community
    components, so one :func:`connected_components` pass over the arcs whose
    endpoints share a label does the whole job. On an out-of-core store the
    same-label filter is applied chunk-by-chunk and the union-find streams
    (:func:`connected_components_chunks`).
    """
    labels = np.asarray(labels, dtype=np.int64)
    if getattr(g, "out_of_core", False):
        def chunks():
            for ch in g.iter_csr_chunks():
                same = labels[ch.src] == labels[ch.dst]
                yield ch.src[same], ch.dst[same]
        with obs.span("engine.split_components", n=int(g.n), chunked=True):
            return connected_components_chunks(g.n, chunks)
    with obs.span("engine.split_components", n=int(g.n)):
        # in-RAM: pull the arcs through the chunk protocol too (a single
        # zero-copy chunk — same arrays arcs() returns), so chunk accounting
        # covers both backends uniformly
        parts = []
        for ch in g.iter_csr_chunks():
            same = labels[ch.src] == labels[ch.dst]
            parts.append((ch.src[same], ch.dst[same]))
        if len(parts) == 1:
            src, dst = parts[0]
        else:
            src = np.concatenate([p[0] for p in parts])
            dst = np.concatenate([p[1] for p in parts])
        return connected_components(g.n, src, dst)


# ---------------------------------------------------------------------------
# the mutable community state driving Fusion
# ---------------------------------------------------------------------------

class CommunityState:
    """Labels + sizes/degrees + an incrementally-merged community adjacency.

    The adjacency is one sorted array pair (neighbor ids, cut weights) per
    community, sliced out of :func:`quotient_edges` at construction. A merge
    of ``b`` into ``a`` concatenates the two lists and re-canonicalizes only
    ``a`` — O(deg(a) + deg(b)) array work. Neighbor lists that still mention
    ``b`` are left stale and resolved lazily through the union-find on read
    (``neighbors``): stale ids map to their live root, entries that became
    internal drop out, duplicates merge by summing. This keeps every Fusion
    event at O(deg log deg) instead of touching all |C| communities.
    """

    def __init__(self, g, labels: np.ndarray,
                 sizes: Optional[np.ndarray] = None):
        labels = np.asarray(labels, dtype=np.int64)
        q = quotient_edges(g, labels)
        num = q.k
        self.num = num
        self.labels = labels
        if sizes is None:
            self.size = np.bincount(labels, minlength=num).astype(np.float64)
        else:
            self.size = np.asarray(sizes, dtype=np.float64).copy()
        # weighted degree per community = inter cut + 2 * intra weight.
        # (bincount of an empty array yields int64 even with weights, so
        # cast — a labelling can have zero inter-community arcs.)
        self.degree = np.bincount(q.src, weights=q.weight,
                                  minlength=num).astype(np.float64)
        self.degree += 2.0 * q.intra
        self.alive = np.ones(num, dtype=bool)
        self.parent = np.arange(num, dtype=np.int64)
        indptr = q.indptr()
        self._nbrs: List[np.ndarray] = [
            q.dst[indptr[c]:indptr[c + 1]] for c in range(num)]
        self._wgts: List[np.ndarray] = [
            q.weight[indptr[c]:indptr[c + 1]] for c in range(num)]

    # ----- union-find ------------------------------------------------------
    def _resolve(self, ids: np.ndarray) -> np.ndarray:
        """Map (possibly stale) community ids to their live roots."""
        while True:
            up = self.parent[ids]
            if np.array_equal(up, ids):
                return ids
            ids = up

    def roots(self) -> np.ndarray:
        """Live root of every original community id."""
        return self._resolve(np.arange(self.num, dtype=np.int64))

    def compact_labels(self) -> np.ndarray:
        """Node labels remapped through the merges, compacted to 0..k-1."""
        root = self.roots()
        _, compact = np.unique(root, return_inverse=True)
        return compact[self.labels]

    # ----- adjacency -------------------------------------------------------
    def _canonicalize(self, c: int) -> None:
        ids = self._resolve(self._nbrs[c])
        ws = self._wgts[c]
        live = ids != c                     # merged-in entries became intra
        ids, ws = ids[live], ws[live]
        if ids.size > 1:
            order = np.argsort(ids, kind="stable")
            ids, ws = ids[order], ws[order]
            starts = np.flatnonzero(np.r_[True, ids[1:] != ids[:-1]])
            ids = ids[starts]
            ws = np.add.reduceat(ws, starts)
        self._nbrs[c], self._wgts[c] = ids, ws

    def neighbors(self, c: int) -> Tuple[np.ndarray, np.ndarray]:
        """(live neighbor ids, cut weights) of live community ``c``,
        canonicalized (sorted, deduped, stale ids resolved)."""
        self._canonicalize(c)
        return self._nbrs[c], self._wgts[c]

    # ----- merge -----------------------------------------------------------
    def merge(self, b: int, into: int) -> None:
        """Merge live community ``b`` into live community ``into``."""
        a = int(into)
        b = int(b)
        self.parent[b] = a
        self.alive[b] = False
        self.size[a] += self.size[b]
        self.degree[a] += self.degree[b]
        self._nbrs[a] = np.concatenate([self._nbrs[a], self._nbrs[b]])
        self._wgts[a] = np.concatenate([self._wgts[a], self._wgts[b]])
        self._nbrs[b] = np.zeros(0, dtype=np.int64)
        self._wgts[b] = np.zeros(0, dtype=np.float64)
        self._canonicalize(a)
