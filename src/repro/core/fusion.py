"""Community Fusion — Algorithms 1 and 2 of the paper.

Greedy merge loop: repeatedly take the smallest community ``c_min`` and merge
it into its largest-edge-cut neighbor that stays under ``max_part_size``
(Algorithm 2 falls back to the *smallest* neighbor when every merge would
overflow), until exactly ``k`` communities remain.

The inter-community cut weights are maintained incrementally in a dict-of-
dict sparse structure so each merge is O(deg(c_min) + deg(c_max_cut)) instead
of a full recount — this is what makes LF *faster* for larger k (Table 3).
"""
from __future__ import annotations

import heapq
from typing import Dict, Optional, Tuple

import numpy as np

from .graph import Graph
from .leiden import leiden


def community_cuts(g: Graph, labels: np.ndarray) -> Dict[int, Dict[int, float]]:
    """cuts[a][b] = total edge weight between communities a and b (a != b)."""
    src, dst, w = g.arcs()
    ls, ld = labels[src], labels[dst]
    keep = ls != ld
    cuts: Dict[int, Dict[int, float]] = {}
    for a, b, ww in zip(ls[keep], ld[keep], w[keep]):
        a, b = int(a), int(b)
        cuts.setdefault(a, {})
        cuts[a][b] = cuts[a].get(b, 0.0) + ww  # each arc counted once per dir
    return cuts


def fuse(g: Graph, labels: np.ndarray, k: int, max_part_size: float,
         sizes: Optional[np.ndarray] = None) -> np.ndarray:
    """Algorithm 1 lines 5-10: merge until |C| == k. Returns new labels.

    ``sizes`` optionally provides the size (node count) per community; by
    default each node counts 1.
    """
    labels = np.asarray(labels, dtype=np.int64).copy()
    num = int(labels.max()) + 1
    if num <= k:
        return labels
    size = np.zeros(num, dtype=np.float64)
    if sizes is None:
        np.add.at(size, labels, 1.0)
    else:
        size[:] = sizes
    cuts = community_cuts(g, labels)
    alive = np.ones(num, dtype=bool)
    # min-heap of (size, comm) with lazy invalidation
    heap: list[Tuple[float, int]] = [(size[c], c) for c in range(num)]
    heapq.heapify(heap)
    # union-find to remap labels at the end
    parent = np.arange(num, dtype=np.int64)

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = int(parent[x])
        return x

    remaining = num
    while remaining > k:
        # --- c_min: smallest live community -------------------------------
        while True:
            s, c_min = heapq.heappop(heap)
            if alive[c_min] and s == size[c_min]:
                break
        nbrs = cuts.get(c_min, {})
        live_nbrs = [(c, w) for c, w in nbrs.items() if alive[c]]
        if not live_nbrs:
            # disconnected community (cannot happen for a connected input
            # graph, see paper §4.3) — merge with the smallest live community
            others = [c for c in range(num) if alive[c] and c != c_min]
            target = min(others, key=lambda c: size[c])
            w = 0.0
            live_nbrs = [(target, w)]
        # --- Algorithm 2: LargestEdgeCutNeighbor ---------------------------
        fitting = [(c, w) for c, w in live_nbrs
                   if size[c] + size[c_min] < max_part_size]
        if fitting:
            # arg max cut; ties broken by smaller size for balance
            c_max_cut = max(fitting, key=lambda cw: (cw[1], -size[cw[0]]))[0]
        else:
            c_max_cut = min(live_nbrs, key=lambda cw: size[cw[0]])[0]
        # --- merge c_min into c_max_cut ------------------------------------
        a, b = int(c_max_cut), int(c_min)
        parent[b] = a
        alive[b] = False
        size[a] += size[b]
        # fold b's cut lists into a's
        cuts_a = cuts.setdefault(a, {})
        for c, w in cuts.pop(b, {}).items():
            if c == a or not alive[c]:
                continue
            cuts_a[c] = cuts_a.get(c, 0.0) + w
            cuts_c = cuts.setdefault(c, {})
            cuts_c[a] = cuts_c.get(a, 0.0) + w
            cuts_c.pop(b, None)
        cuts_a.pop(b, None)
        heapq.heappush(heap, (size[a], a))
        remaining -= 1

    # remap to compact 0..k-1
    root = np.array([find(int(c)) for c in range(num)], dtype=np.int64)
    _, compact = np.unique(root, return_inverse=True)
    return compact[labels]


def leiden_fusion(g: Graph, k: int, alpha: float = 0.05, beta: float = 0.5,
                  seed: int = 0, gamma: float = 1.0) -> np.ndarray:
    """Algorithm 1 — the full Leiden-Fusion partitioner.

    max_part_size = (n/k)(1+alpha);  Leiden cap = beta * max_part_size;
    ``gamma`` is the Leiden modularity resolution (higher -> more, smaller
    communities entering the fusion stage). Exposed through the v2 spec
    grammar as ``"leiden_fusion(resolution=...)"``.
    """
    max_part_size = (g.n / k) * (1.0 + alpha)
    labels = leiden(g, max_community_size=beta * max_part_size, seed=seed,
                    gamma=gamma)
    return fuse(g, labels, k, max_part_size)
