"""Community Fusion — Algorithms 1 and 2 of the paper.

Greedy merge loop: repeatedly take the smallest community ``c_min`` and merge
it into its largest-edge-cut neighbor that stays under ``max_part_size``
(Algorithm 2 falls back to the *smallest* neighbor when every merge would
overflow), until exactly ``k`` communities remain.

The loop is driven by :class:`repro.core.engine.CommunityState`: the
inter-community cuts live in per-community *sorted arrays* built once from
the engine's quotient-graph pass and merged incrementally, so each merge is
O(deg(c_min) + deg(c_max_cut)) array work instead of a full recount — this
is what makes LF *faster* for larger k (Table 3). The disconnected-community
fallback (which cannot trigger for a connected input graph, paper §4.3) pops
the smallest other live community from the same lazy min-heap that drives
``c_min`` selection — O(log |C|) amortized, not an O(|C|) scan per event.
"""
from __future__ import annotations

import heapq
from typing import Dict, Optional

import numpy as np

from .engine import CommunityState, quotient_edges
from .graph import Graph
from .leiden import leiden


def community_cuts(g: Graph, labels: np.ndarray) -> Dict[int, Dict[int, float]]:
    """cuts[a][b] = total edge weight between communities a and b (a != b).

    Compatibility view over :func:`repro.core.engine.quotient_edges` (the
    one quotient-graph/cut implementation); Fusion itself consumes the
    array form via :class:`~repro.core.engine.CommunityState`.
    """
    q = quotient_edges(g, labels)
    cuts: Dict[int, Dict[int, float]] = {}
    for a, b, w in zip(q.src.tolist(), q.dst.tolist(), q.weight.tolist()):
        cuts.setdefault(a, {})[b] = w
    return cuts


def _pop_live(heap, state: CommunityState, skip: int = -1) -> int:
    """Pop the smallest live community (lazy invalidation); ``skip`` is
    excluded (used by the disconnected fallback, where ``c_min`` itself must
    not be returned). Popped-but-valid entries are consumed: the caller
    either merges the result away or re-pushes it."""
    size = state.size
    alive = state.alive
    while True:
        s, c = heapq.heappop(heap)
        if c != skip and alive[c] and s == size[c]:
            return c


def fuse(g: Graph, labels: np.ndarray, k: int, max_part_size: float,
         sizes: Optional[np.ndarray] = None) -> np.ndarray:
    """Algorithm 1 lines 5-10: merge until |C| == k. Returns new labels.

    ``sizes`` optionally provides the size (node count) per community; by
    default each node counts 1.
    """
    labels = np.asarray(labels, dtype=np.int64).copy()
    num = int(labels.max()) + 1
    if num <= k:
        return labels
    state = CommunityState(g, labels, sizes=sizes)
    size = state.size
    # min-heap of (size, comm) with lazy invalidation
    heap = [(size[c], c) for c in range(num)]
    heapq.heapify(heap)

    remaining = num
    while remaining > k:
        # --- c_min: smallest live community -------------------------------
        c_min = _pop_live(heap, state)
        nbrs, cut_w = state.neighbors(c_min)
        if nbrs.size:
            # --- Algorithm 2: LargestEdgeCutNeighbor -----------------------
            fits = size[nbrs] + size[c_min] < max_part_size
            if fits.any():
                fid, fw = nbrs[fits], cut_w[fits]
                # arg max cut; ties broken by smaller size for balance,
                # then smaller id for determinism
                target = int(fid[np.lexsort((fid, size[fid], -fw))[0]])
            else:
                # every merge would overflow: take the smallest neighbor
                target = int(nbrs[np.lexsort((nbrs, size[nbrs]))[0]])
        else:
            # disconnected community — merge with the smallest other live
            # community, straight off the heap
            target = _pop_live(heap, state, skip=c_min)
        # --- merge c_min into target ---------------------------------------
        state.merge(c_min, into=target)
        heapq.heappush(heap, (size[target], target))
        remaining -= 1

    return state.compact_labels()


def leiden_fusion(g: Graph, k: int, alpha: float = 0.05, beta: float = 0.5,
                  seed: int = 0, gamma: float = 1.0) -> np.ndarray:
    """Algorithm 1 — the full Leiden-Fusion partitioner.

    max_part_size = (n/k)(1+alpha);  Leiden cap = beta * max_part_size;
    ``gamma`` is the Leiden modularity resolution (higher -> more, smaller
    communities entering the fusion stage). Exposed through the v2 spec
    grammar as ``"leiden_fusion(resolution=...)"``.

    Leiden returns connected communities and Fusion only ever merges a
    community into a community it shares an edge with, so for a connected
    input every output partition is one connected component with no
    isolated nodes (the paper's central guarantee).
    """
    max_part_size = (g.n / k) * (1.0 + alpha)
    labels = leiden(g, max_community_size=beta * max_part_size, seed=seed,
                    gamma=gamma)
    return fuse(g, labels, k, max_part_size)
