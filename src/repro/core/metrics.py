"""Partition quality metrics — paper §5.1, equations (5)-(7).

Fully vectorized on top of :mod:`repro.core.engine`: per-partition node and
edge counts via ``bincount``, per-partition components via the engine's
array union-find, halo pairs via ``np.unique`` over ``(part, node)`` keys.
No Python loop touches nodes or edges, so evaluating a 500k-node partition
is sub-second.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from .engine import connected_components, connected_components_chunks
from .graph import Graph


@dataclasses.dataclass(frozen=True)
class PartitionReport:
    k: int
    edge_cut_pct: float          # eq. (5), in percent of total edges
    components_per_part: List[int]
    isolated_per_part: List[int]
    node_balance: float          # eq. (6)
    edge_balance: float
    replication_factor: float    # eq. (7), with 1-hop halos (Repli scheme)

    @property
    def total_components(self) -> int:
        return int(sum(self.components_per_part))

    @property
    def total_isolated(self) -> int:
        return int(sum(self.isolated_per_part))

    @property
    def max_components(self) -> int:
        return int(max(self.components_per_part))

    def as_dict(self) -> Dict[str, float]:
        return {
            "k": self.k,
            "edge_cut_pct": self.edge_cut_pct,
            "total_components": self.total_components,
            "max_components": self.max_components,
            "total_isolated": self.total_isolated,
            "node_balance": self.node_balance,
            "edge_balance": self.edge_balance,
            "replication_factor": self.replication_factor,
        }


def _evaluate_partition_chunked(g, labels: np.ndarray,
                                k: int) -> PartitionReport:
    """Out-of-core body of :func:`evaluate_partition`: the same counts,
    accumulated over ``iter_csr_chunks()`` sweeps instead of one
    whole-array ``arcs()`` pass. Peak RAM is O(n + k + halo pairs)."""
    n = g.n
    m_once = 0
    cut = 0
    edges = np.zeros(k, dtype=np.int64)
    deg = np.zeros(n, dtype=np.int64)       # intra-partition degree
    halo_parts: List[np.ndarray] = []
    for ch in g.iter_csr_chunks():
        once = ch.src < ch.dst              # count each edge once
        s, d = ch.src[once], ch.dst[once]
        m_once += int(s.size)
        cut_mask = labels[s] != labels[d]
        cut += int(cut_mask.sum())
        si, di = s[~cut_mask], d[~cut_mask]
        edges += np.bincount(labels[si], minlength=k).astype(np.int64)
        deg += np.bincount(si, minlength=n) + np.bincount(di, minlength=n)
        cs, cd = s[cut_mask], d[cut_mask]
        hk = np.unique(np.concatenate([labels[cs] * n + cd,
                                       labels[cd] * n + cs]))
        if hk.size:
            halo_parts.append(hk)
    nodes = np.bincount(labels, minlength=k)
    isolated = np.bincount(labels[deg == 0], minlength=k)

    def intra_chunks():
        for ch in g.iter_csr_chunks():
            same = labels[ch.src] == labels[ch.dst]
            yield ch.src[same], ch.dst[same]
    comp = connected_components_chunks(n, intra_chunks)
    _, rep = np.unique(comp, return_index=True)
    comps = np.bincount(labels[rep], minlength=k)

    node_balance = nodes.max() / (n / k)
    edge_balance = edges.max() / (max(int(edges.sum()), 1) / k)
    halo_keys = (np.unique(np.concatenate(halo_parts)) if halo_parts
                 else np.zeros(0, dtype=np.int64))
    rf = (n + halo_keys.size) / n
    return PartitionReport(k=k, edge_cut_pct=float(100.0 * cut
                                                   / max(m_once, 1)),
                           components_per_part=[int(c) for c in comps],
                           isolated_per_part=[int(i) for i in isolated],
                           node_balance=float(node_balance),
                           edge_balance=float(edge_balance),
                           replication_factor=float(rf))


def evaluate_partition(g: Graph, labels: np.ndarray) -> PartitionReport:
    labels = np.asarray(labels, dtype=np.int64)
    k = int(labels.max()) + 1
    if getattr(g, "out_of_core", False):
        return _evaluate_partition_chunked(g, labels, k)
    src, dst, w = g.arcs()
    once = src < dst                      # count each undirected edge once
    s, d = src[once], dst[once]
    m = s.shape[0]
    cut_mask = labels[s] != labels[d]
    edge_cut_pct = 100.0 * cut_mask.sum() / max(m, 1)

    # per-partition structure — all bincounts over the intra-partition
    # edge subgraph
    same = ~cut_mask
    si, di = s[same], d[same]
    nodes = np.bincount(labels, minlength=k)
    edges = np.bincount(labels[si], minlength=k)
    deg = np.bincount(si, minlength=g.n) + np.bincount(di, minlength=g.n)
    isolated = np.bincount(labels[deg == 0], minlength=k)
    # components of the intra-partition subgraph ARE the per-partition
    # components; one union-find pass, then count components per partition
    # via each component's representative node
    comp = connected_components(g.n, si, di)
    _, rep = np.unique(comp, return_index=True)
    comps = np.bincount(labels[rep], minlength=k)

    node_balance = nodes.max() / (g.n / k)
    edge_balance = edges.max() / (max(int(edges.sum()), 1) / k)

    # replication factor with 1-hop halos: each partition stores its own
    # nodes + boundary neighbors in other partitions — deduped (part, node)
    # keys over the cut edges
    cs, cd = s[cut_mask], d[cut_mask]
    halo_keys = np.unique(np.concatenate([labels[cs] * g.n + cd,
                                          labels[cd] * g.n + cs]))
    rf = (g.n + halo_keys.size) / g.n

    return PartitionReport(k=k, edge_cut_pct=float(edge_cut_pct),
                           components_per_part=[int(c) for c in comps],
                           isolated_per_part=[int(i) for i in isolated],
                           node_balance=float(node_balance),
                           edge_balance=float(edge_balance),
                           replication_factor=float(rf))
