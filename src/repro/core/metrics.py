"""Partition quality metrics — paper §5.1, equations (5)-(7)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from .graph import Graph


@dataclasses.dataclass(frozen=True)
class PartitionReport:
    k: int
    edge_cut_pct: float          # eq. (5), in percent of total edges
    components_per_part: List[int]
    isolated_per_part: List[int]
    node_balance: float          # eq. (6)
    edge_balance: float
    replication_factor: float    # eq. (7), with 1-hop halos (Repli scheme)

    @property
    def total_components(self) -> int:
        return int(sum(self.components_per_part))

    @property
    def total_isolated(self) -> int:
        return int(sum(self.isolated_per_part))

    @property
    def max_components(self) -> int:
        return int(max(self.components_per_part))

    def as_dict(self) -> Dict[str, float]:
        return {
            "k": self.k,
            "edge_cut_pct": self.edge_cut_pct,
            "total_components": self.total_components,
            "max_components": self.max_components,
            "total_isolated": self.total_isolated,
            "node_balance": self.node_balance,
            "edge_balance": self.edge_balance,
            "replication_factor": self.replication_factor,
        }


def evaluate_partition(g: Graph, labels: np.ndarray) -> PartitionReport:
    labels = np.asarray(labels, dtype=np.int64)
    k = int(labels.max()) + 1
    src, dst, w = g.arcs()
    once = src < dst                      # count each undirected edge once
    s, d = src[once], dst[once]
    m = s.shape[0]
    cut_mask = labels[s] != labels[d]
    edge_cut_pct = 100.0 * cut_mask.sum() / max(m, 1)

    # per-partition structure
    comps, isolated, nodes, edges = [], [], [], []
    deg = np.zeros(g.n, dtype=np.int64)
    same = ~cut_mask
    np.add.at(deg, s[same], 1)
    np.add.at(deg, d[same], 1)
    for p in range(k):
        mask = labels == p
        nodes.append(int(mask.sum()))
        edges.append(int((same & (labels[s] == p)).sum()))
        comps.append(g.num_components(mask))
        isolated.append(int(((deg == 0) & mask).sum()))

    node_balance = max(nodes) / (g.n / k)
    edge_balance = max(edges) / (max(sum(edges), 1) / k)

    # replication factor with 1-hop halos: each partition stores its own
    # nodes + boundary neighbors in other partitions
    halo_pairs = set()
    for a, b in zip(s[cut_mask], d[cut_mask]):
        halo_pairs.add((int(labels[a]), int(b)))
        halo_pairs.add((int(labels[b]), int(a)))
    rf = (g.n + len(halo_pairs)) / g.n

    return PartitionReport(k=k, edge_cut_pct=float(edge_cut_pct),
                           components_per_part=comps,
                           isolated_per_part=isolated,
                           node_balance=float(node_balance),
                           edge_balance=float(edge_balance),
                           replication_factor=float(rf))
