"""Core — the paper's contribution: Leiden-Fusion partitioning."""
from .graph import Graph, NodeDataset, karate_club, make_arxiv_like, make_proteins_like
from .leiden import leiden
from .fusion import fuse, leiden_fusion, community_cuts
from .partitioners import (PARTITIONERS, get_partitioner, lpa_partition,
                           metis_partition, random_partition,
                           single_partition, with_fusion,
                           split_into_components)
from .metrics import PartitionReport, evaluate_partition
from .assemble import (PartitionBatch, HaloExchangeSpec,
                       build_partition_batch, build_halo_exchange)

__all__ = [
    "Graph", "NodeDataset", "karate_club", "make_arxiv_like",
    "make_proteins_like", "leiden", "fuse", "leiden_fusion", "community_cuts",
    "PARTITIONERS", "get_partitioner", "lpa_partition", "metis_partition",
    "random_partition", "single_partition", "with_fusion",
    "split_into_components",
    "PartitionReport", "evaluate_partition", "PartitionBatch",
    "HaloExchangeSpec", "build_partition_batch", "build_halo_exchange",
]
