"""Core — the paper's contribution: Leiden-Fusion partitioning."""
from .engine import (ArcChunk, CommunityState, QuotientEdges,
                     connected_components, connected_components_chunks,
                     quotient_edges, split_components)
from .graph import Graph, NodeDataset, karate_club, make_arxiv_like, make_proteins_like
from .graphstore import (STORE_FORMAT_VERSION, GraphStoreError,
                         GraphStoreIntegrityError, MmapGraphStore,
                         atomic_directory, build_store_from_edge_batches,
                         store_from_graph)
from .leiden import leiden
from .fusion import fuse, leiden_fusion, community_cuts
from .registry import (Capabilities, FusionConfig, NullConfig, Partitioner,
                       RegisteredPartitioner, register_partitioner,
                       unregister_partitioner, registered_partitioners,
                       get_entry)
from .partitioners import (PARTITIONERS, get_partitioner, lpa_partition,
                           metis_partition, random_partition,
                           single_partition, with_fusion,
                           split_into_components,
                           SingleConfig, RandomConfig, LpaConfig,
                           MetisConfig, LeidenFusionConfig)
from .spec import (PartitionResult, PartitionerSpec, partition_from_spec,
                   parse_spec_text)
from .metrics import PartitionReport, evaluate_partition
from .assemble import (INTEGRATION_KINDS, PartitionBatch, HaloExchangeSpec,
                       average_partition_params, build_partition_batch,
                       build_halo_exchange, integrate_models)

__all__ = [
    # the vectorized partitioning engine (DESIGN.md §10)
    "ArcChunk", "CommunityState", "QuotientEdges", "connected_components",
    "connected_components_chunks", "quotient_edges", "split_components",
    # the out-of-core GraphStore backend (DESIGN.md §15)
    "STORE_FORMAT_VERSION", "GraphStoreError", "GraphStoreIntegrityError",
    "MmapGraphStore", "atomic_directory", "build_store_from_edge_batches",
    "store_from_graph",
    "Graph", "NodeDataset", "karate_club", "make_arxiv_like",
    "make_proteins_like", "leiden", "fuse", "leiden_fusion", "community_cuts",
    # partitioner API v2
    "Capabilities", "FusionConfig", "NullConfig", "Partitioner",
    "RegisteredPartitioner", "register_partitioner",
    "unregister_partitioner", "registered_partitioners", "get_entry",
    "PartitionResult", "PartitionerSpec", "partition_from_spec",
    "parse_spec_text",
    "SingleConfig", "RandomConfig", "LpaConfig", "MetisConfig",
    "LeidenFusionConfig",
    # v1 shims + functional forms
    "PARTITIONERS", "get_partitioner", "lpa_partition", "metis_partition",
    "random_partition", "single_partition", "with_fusion",
    "split_into_components",
    "PartitionReport", "evaluate_partition", "PartitionBatch",
    "HaloExchangeSpec", "build_partition_batch", "build_halo_exchange",
    # model integration (DESIGN.md §12)
    "INTEGRATION_KINDS", "average_partition_params", "integrate_models",
]
