"""Partitioner spec strings — parse/format/execute (DESIGN.md §9).

The spec mini-language selects any registered partitioner, configured, from
one string — usable from the CLI, :class:`repro.pipeline.PipelineConfig`,
and the benchmarks:

    spec   := method [ "(" args ")" ] [ "+f" [ "(" args ")" ] ]
    method := [A-Za-z_][A-Za-z0-9_-]*        (normalized: lower, "-" -> "_")
    args   := [ name "=" value {"," name "=" value} ]
    value  := int | float | true | false | none | 'string' | bareword

Examples: ``"metis"``, ``"lpa(max_iter=30,balance_cap=1.5)"``,
``"metis+f(alpha=0.1)"``, ``"leiden_fusion(resolution=0.5)"``.

``+f`` is the paper's §5.4 fusion operator as a first-class combinator over
*any* registered base method (configured by
:class:`~repro.core.registry.FusionConfig`), replacing the old hardcoded
``metis_f``/``lpa_f`` lambdas.

Canonical form (``PartitionerSpec.canonical()``) prints only non-default
fields in declaration order, so ``format(parse(s))`` is idempotent and
``"lpa(max_iter=50)"`` canonicalizes to ``"lpa"``. The *fingerprint* hashes
the fully-resolved config (every field, defaults included) plus the method
name — it is the artifact-cache key component that keeps differently-
parameterized runs from colliding on one cached bundle.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import re
import time
import types
import typing
from typing import Any, Dict, Optional, Tuple, Union

import numpy as np

from .graph import Graph
from .registry import FusionConfig, get_entry, registered_partitioners

__all__ = ["PartitionResult", "PartitionerSpec", "partition_from_spec",
           "parse_spec_text", "format_value"]


# ---------------------------------------------------------------------------
# grammar: text -> (method, args, fusion_args | None)
# ---------------------------------------------------------------------------

_NAME = r"[A-Za-z_][A-Za-z0-9_-]*"
# an args blob is anything paren-free, except that quoted string values may
# contain parens (so canonical() output always re-parses)
_ARGS = r"(?:[^()'\"]|'[^']*'|\"[^\"]*\")*?"
_SPEC_RE = re.compile(
    rf"^\s*(?P<method>{_NAME})\s*(?:\(\s*(?P<args>{_ARGS})\s*\))?"
    rf"\s*(?P<fusion>\+\s*[fF]\s*(?:\(\s*(?P<fargs>{_ARGS})\s*\))?)?\s*$")
_BARE_RE = re.compile(rf"^{_NAME}$")


def _parse_value(token: str, spec: str) -> Any:
    t = token.strip()
    low = t.lower()
    if low in ("true", "false"):
        return low == "true"
    if low in ("none", "null"):
        return None
    try:
        return int(t)
    except ValueError:
        pass
    try:
        return float(t)
    except ValueError:
        pass
    if len(t) >= 2 and t[0] == t[-1] and t[0] in "'\"":
        return t[1:-1]
    if _BARE_RE.match(t):
        return t
    raise ValueError(f"bad spec {spec!r}: cannot parse value {token!r}")


def _split_args(blob: str) -> list:
    """Split on commas, but not inside quoted string values."""
    parts, buf, quote = [], [], None
    for ch in blob:
        if quote:
            buf.append(ch)
            if ch == quote:
                quote = None
        elif ch in "'\"":
            quote = ch
            buf.append(ch)
        elif ch == ",":
            parts.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    parts.append("".join(buf))
    return parts


def _parse_args(blob: Optional[str], spec: str) -> Optional[Dict[str, Any]]:
    if blob is None:
        return None
    args: Dict[str, Any] = {}
    blob = blob.strip()
    if not blob:
        return args
    for part in _split_args(blob):
        if "=" not in part:
            raise ValueError(f"bad spec {spec!r}: expected name=value, "
                             f"got {part.strip()!r}")
        name, value = part.split("=", 1)
        name = name.strip().lower()
        if not _BARE_RE.match(name):
            raise ValueError(f"bad spec {spec!r}: bad field name {name!r}")
        if name in args:
            raise ValueError(f"bad spec {spec!r}: duplicate field {name!r}")
        args[name] = _parse_value(value, spec)
    return args


def parse_spec_text(text: str) -> Tuple[str, Dict[str, Any],
                                        Optional[Dict[str, Any]]]:
    """Syntactic parse only (no registry lookup).

    Returns ``(method, args, fusion_args)``; ``fusion_args`` is ``None``
    when the spec has no ``+f`` suffix, ``{}`` for a bare ``+f``.
    """
    m = _SPEC_RE.match(text or "")
    if not m:
        raise ValueError(
            f"bad partitioner spec {text!r}; expected "
            f"\"method\", \"method(field=value,...)\", or \"method+f(...)\"")
    method = m.group("method").lower().replace("-", "_")
    args = _parse_args(m.group("args"), text) or {}
    fargs = None
    if m.group("fusion") is not None:
        fargs = _parse_args(m.group("fargs") or "", text)
    return method, args, fargs


# ---------------------------------------------------------------------------
# typed config construction
# ---------------------------------------------------------------------------

def _coerce(value: Any, annot: Any, field: str, where: str) -> Any:
    origin = typing.get_origin(annot)
    # typing.Optional/Union and PEP 604 `T | None` (types.UnionType)
    if origin is Union or origin is getattr(types, "UnionType", None):
        members = typing.get_args(annot)
        if value is None and type(None) in members:
            return None
        for member in members:
            if member is type(None):
                continue
            try:
                return _coerce(value, member, field, where)
            except (TypeError, ValueError):
                pass
        raise TypeError(f"{where}: field {field!r} expects {annot}, "
                        f"got {value!r}")
    if annot is bool:
        if isinstance(value, bool):
            return value
    elif annot is int:
        if isinstance(value, bool):
            pass
        elif isinstance(value, int):
            return value
        elif isinstance(value, float) and value.is_integer():
            return int(value)
    elif annot is float:
        if isinstance(value, bool):
            pass
        elif isinstance(value, (int, float)):
            v = float(value)
            if not math.isfinite(v):
                raise ValueError(f"{where}: field {field!r} must be finite, "
                                 f"got {value!r}")
            return v
    elif annot is str:
        if isinstance(value, str):
            return value
    else:
        return value                        # unconstrained annotation
    raise TypeError(f"{where}: field {field!r} expects "
                    f"{getattr(annot, '__name__', annot)}, got {value!r}")


def build_config(config_type: type, args: Dict[str, Any], where: str) -> Any:
    """Instantiate a frozen config dataclass from parsed spec args, with
    field-name validation and int/float coercion."""
    hints = typing.get_type_hints(config_type)
    fields = {f.name: f for f in dataclasses.fields(config_type)}
    kwargs = {}
    for name, value in args.items():
        if name not in fields:
            raise ValueError(
                f"unknown field {name!r} for partitioner {where!r}; "
                f"expected: {', '.join(fields) or '(no fields)'}")
        kwargs[name] = _coerce(value, hints.get(name, Any), name, where)
    return config_type(**kwargs)


def format_value(v: Any) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if v is None:
        return "none"
    if isinstance(v, float):
        return repr(v)
    if isinstance(v, str):
        # barewords round-trip unquoted, unless they would re-parse as a
        # keyword; anything else (commas, '=', spaces, digits) is quoted
        if _BARE_RE.match(v) and v.lower() not in ("true", "false", "none",
                                                   "null"):
            return v
        q = '"' if "'" in v else "'"
        return f"{q}{v}{q}"
    return str(v)


def _format_args(config: Any) -> str:
    parts = []
    for f in dataclasses.fields(config):
        v = getattr(config, f.name)
        default = f.default if f.default is not dataclasses.MISSING else \
            (f.default_factory() if f.default_factory is not dataclasses.MISSING
             else dataclasses.MISSING)
        if v != default:
            parts.append(f"{f.name}={format_value(v)}")
    return f"({','.join(parts)})" if parts else ""


# ---------------------------------------------------------------------------
# the typed spec + result
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PartitionResult:
    """Structured output of one partitioner run: labels + the canonical
    spec, the config fingerprint (the artifact-cache key component), and
    run provenance/timings."""
    labels: np.ndarray
    spec: str                       # canonical spec string
    fingerprint: str                # hash of method + full resolved config
    k: int
    seed: int
    seconds: float
    provenance: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def num_parts(self) -> int:
        return int(self.labels.max()) + 1 if self.labels.size else 0


@dataclasses.dataclass(frozen=True)
class PartitionerSpec:
    """A fully-resolved partitioner selection: method + typed config +
    optional ``+f`` fusion combinator."""
    method: str
    config: Any
    fusion: Optional[FusionConfig] = None

    # ----- construction ----------------------------------------------------
    @classmethod
    def parse(cls, text: Union[str, "PartitionerSpec"]) -> "PartitionerSpec":
        if isinstance(text, PartitionerSpec):
            return text
        method, args, fargs = parse_spec_text(text)
        names = registered_partitioners()
        if method not in names and method.endswith("_f") \
                and method[:-2] in names:
            # legacy alias: "metis_f" == "metis+f" (bare form only)
            if args or fargs is not None:
                raise ValueError(
                    f"bad spec {text!r}: the legacy {method!r} alias takes "
                    f"no arguments — use \"{method[:-2]}+f(...)\"")
            method, fargs = method[:-2], {}
        entry = get_entry(method)           # ValueError on unknown method
        config = build_config(entry.config_type, args, method)
        fusion = None
        if fargs is not None:
            fusion = build_config(FusionConfig, fargs, f"{method}+f")
        return cls(method=entry.name, config=config, fusion=fusion)

    # ----- formatting ------------------------------------------------------
    def canonical(self) -> str:
        s = self.method + _format_args(self.config)
        if self.fusion is not None:
            s += "+f" + _format_args(self.fusion)
        return s

    def __str__(self) -> str:
        return self.canonical()

    # ----- identity --------------------------------------------------------
    def fingerprint(self) -> str:
        """16-hex-char digest over the method name and the *full* resolved
        config (defaults included) — stable across processes."""
        payload = {"method": self.method,
                   "config": dataclasses.asdict(self.config),
                   "fusion": (dataclasses.asdict(self.fusion)
                              if self.fusion is not None else None)}
        blob = json.dumps(payload, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]

    @property
    def capabilities(self):
        caps = get_entry(self.method).capabilities
        if self.fusion is not None:
            # +f splits every partition into components and fuses neighbors,
            # so connectivity holds regardless of the base. Balance is NOT
            # upgraded: fuse() caps merges only best-effort (it returns
            # early when the base yields <= k components and overflows the
            # cap when no fitting neighbor exists), so the base's flag
            # stands.
            caps = dataclasses.replace(caps, connectivity_guaranteed=True)
        return caps

    # ----- execution -------------------------------------------------------
    def partition(self, g: Graph, k: int, seed: int = 0) -> PartitionResult:
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        entry = get_entry(self.method)
        provenance: Dict[str, Any] = {
            "method": self.method,
            "config": dataclasses.asdict(self.config)}
        t0 = time.time()
        if self.fusion is None:
            labels = entry.fn(g, k, seed, self.config)
        else:
            from .fusion import fuse
            from .partitioners import split_into_components
            base_k = self.fusion.base_k or k
            t_base = time.time()
            base_labels = entry.fn(g, base_k, seed, self.config)
            provenance["base_seconds"] = round(time.time() - t_base, 4)
            t_fuse = time.time()
            comms = split_into_components(g, base_labels)
            max_part_size = (g.n / k) * (1.0 + self.fusion.alpha)
            labels = fuse(g, comms, k, max_part_size)
            provenance["fusion"] = dataclasses.asdict(self.fusion)
            provenance["base_communities"] = int(comms.max()) + 1
            provenance["fusion_seconds"] = round(time.time() - t_fuse, 4)
        labels = np.asarray(labels, dtype=np.int64)
        if labels.shape != (g.n,):
            raise ValueError(f"partitioner {self.method!r} returned labels "
                             f"of shape {labels.shape}, expected ({g.n},)")
        return PartitionResult(labels=labels, spec=self.canonical(),
                               fingerprint=self.fingerprint(), k=int(k),
                               seed=int(seed), seconds=time.time() - t0,
                               provenance=provenance)


def partition_from_spec(g: Graph, spec: Union[str, PartitionerSpec], k: int,
                        seed: int = 0) -> PartitionResult:
    """One-call API: ``partition_from_spec(g, "lpa+f(alpha=0.1)", 8)``."""
    return PartitionerSpec.parse(spec).partition(g, k, seed=seed)
