"""Subgraph assembly for distributed local training.

Builds, for every partition, the *Inner* (cut edges dropped) or *Repli*
(1-hop boundary replicas, frozen halo) training subgraph, padded to uniform
static shapes so k subgraphs can be stacked on the ``data`` mesh axis and fed
through one `shard_map`ped train step.

Conventions of the padded CSR batch (`PartitionBatch`):
  - nodes  [k, N_pad]  original node ids, -1 for padding
  - edges are destination-sorted arc lists (src_local, dst_local) so the
    aggregation kernel can stream edge blocks; padding arcs point at a
    dedicated sink row (N_pad-1 reserved? no — padding arcs carry weight 0
    and src=dst=0; they contribute zeros because features are masked).
  - owned mask: True for nodes the partition *owns* (loss + embedding rows);
    halo replicas are present in Repli batches with owned=False.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .graph import Graph


@dataclasses.dataclass(frozen=True)
class PartitionBatch:
    """Static-shape batch of k partition subgraphs (numpy; fed to JAX)."""
    node_ids: np.ndarray      # [k, N_pad] int32, -1 = padding
    node_mask: np.ndarray     # [k, N_pad] bool, valid node
    owned_mask: np.ndarray    # [k, N_pad] bool, owned (not halo) node
    edge_src: np.ndarray      # [k, E_pad] int32 local src (gather index)
    edge_dst: np.ndarray      # [k, E_pad] int32 local dst (segment id), sorted
    edge_weight: np.ndarray   # [k, E_pad] f32, 0 for padding
    in_degree: np.ndarray     # [k, N_pad] f32 (for GCN mean normalization)
    n_pad: int
    e_pad: int

    @property
    def k(self) -> int:
        return int(self.node_ids.shape[0])


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _partition_lists_chunked(g, labels: np.ndarray, k: int, scheme: str
                             ) -> Tuple[List[np.ndarray], List[np.ndarray],
                                        List[Tuple[np.ndarray, np.ndarray,
                                                   np.ndarray]]]:
    """Out-of-core body of :func:`build_partition_batch`: the same
    per-partition node/arc lists, accumulated over ``iter_csr_chunks()``
    sweeps. Chunk pieces concatenate in global arc order and the final
    per-partition sort is the same stable dst-sort, so the assembled lists
    match the in-RAM path element for element. Local ids are kept int32
    (they index into the padded batch, which is int32 anyway), so peak RAM
    is the kept arcs at half the in-RAM width plus O(n) per partition for
    the remap."""
    n = g.n
    # halo discovery first (repli): unique (partition, halo node) keys per
    # chunk, merged at the end — matches np.unique's sorted order per part
    halos: List[np.ndarray] = [np.zeros(0, dtype=np.int64)] * k
    if scheme == "repli":
        parts: List[np.ndarray] = []
        for ch in g.iter_csr_chunks():
            ls, ld = labels[ch.src], labels[ch.dst]
            hm = ls != ld               # src is halo for dst's partition
            hk = np.unique(ld[hm] * n + ch.src[hm])
            if hk.size:
                parts.append(hk)
        if parts:
            keys = np.unique(np.concatenate(parts))
            part_of, node_of = keys // n, keys % n
            halos = [node_of[part_of == p] for p in range(k)]

    node_lists: List[np.ndarray] = []
    owned_lists: List[np.ndarray] = []
    remaps: List[np.ndarray] = []
    for p in range(k):
        owned = np.flatnonzero(labels == p)
        if scheme == "inner":
            nodes = owned
            owned_flags = np.ones(owned.shape[0], dtype=bool)
        else:
            nodes = np.concatenate([owned, halos[p]])
            owned_flags = np.concatenate([
                np.ones(owned.shape[0], dtype=bool),
                np.zeros(halos[p].shape[0], dtype=bool)])
        remap = np.full(n, -1, dtype=np.int32)
        remap[nodes] = np.arange(nodes.shape[0], dtype=np.int32)
        node_lists.append(nodes)
        owned_lists.append(owned_flags)
        remaps.append(remap)

    pieces: List[List[Tuple[np.ndarray, np.ndarray, np.ndarray]]] = \
        [[] for _ in range(k)]
    for ch in g.iter_csr_chunks():
        ls, ld = labels[ch.src], labels[ch.dst]
        for p in range(k):
            keep = (ls == p) & (ld == p) if scheme == "inner" else ld == p
            if not keep.any():
                continue
            pieces[p].append((remaps[p][ch.src[keep]],
                              remaps[p][ch.dst[keep]],
                              ch.weight[keep].astype(np.float32)))
    arc_lists: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
    for p in range(k):
        if pieces[p]:
            pls = np.concatenate([x[0] for x in pieces[p]])
            pld = np.concatenate([x[1] for x in pieces[p]])
            plw = np.concatenate([x[2] for x in pieces[p]])
        else:
            pls = pld = np.zeros(0, dtype=np.int32)
            plw = np.zeros(0, dtype=np.float32)
        pieces[p] = []                  # release as we go
        order = np.argsort(pld, kind="stable")
        arc_lists.append((pls[order], pld[order], plw[order]))
    return node_lists, owned_lists, arc_lists


def build_partition_batch(g: Graph, labels: np.ndarray, scheme: str = "inner",
                          pad_nodes_to: Optional[int] = None,
                          pad_edges_to: Optional[int] = None,
                          align: int = 8) -> PartitionBatch:
    """Assemble the k padded subgraphs for ``scheme`` in {'inner','repli'}."""
    assert scheme in ("inner", "repli"), scheme
    labels = np.asarray(labels, dtype=np.int64)
    k = int(labels.max()) + 1

    if getattr(g, "out_of_core", False):
        node_lists, owned_lists, arc_lists = \
            _partition_lists_chunked(g, labels, k, scheme)
    else:
        src, dst, w = g.arcs()          # every directed arc (u -> v)

        node_lists = []
        owned_lists = []
        arc_lists = []

        for p in range(k):
            owned = np.where(labels == p)[0]
            owned_set = np.zeros(g.n, dtype=bool)
            owned_set[owned] = True
            if scheme == "inner":
                keep = owned_set[src] & owned_set[dst]
                nodes = owned
                owned_flags = np.ones(nodes.shape[0], dtype=bool)
            else:
                # Repli: owned nodes + 1-hop halo; keep every arc whose
                # *dst* is owned (halo feeds owned nodes) plus owned->owned
                # arcs. Arcs into halo nodes are dropped — halo features
                # are frozen inputs.
                keep = owned_set[dst]
                halo = np.unique(src[keep & ~owned_set[src]])
                nodes = np.concatenate([owned, halo])
                owned_flags = np.concatenate([
                    np.ones(owned.shape[0], dtype=bool),
                    np.zeros(halo.shape[0], dtype=bool)])
            remap = np.full(g.n, -1, dtype=np.int64)
            remap[nodes] = np.arange(nodes.shape[0])
            ls, ld, lw = remap[src[keep]], remap[dst[keep]], w[keep]
            # destination-sorted for segment-sum friendliness
            order = np.argsort(ld, kind="stable")
            arc_lists.append((ls[order], ld[order], lw[order]))
            node_lists.append(nodes)
            owned_lists.append(owned_flags)

    n_max = max(x.shape[0] for x in node_lists)
    e_max = max(x[0].shape[0] for x in arc_lists) if arc_lists else 1
    n_pad = pad_nodes_to or _round_up(max(n_max, 1), align)
    e_pad = pad_edges_to or _round_up(max(e_max, 1), align)
    if n_max > n_pad or e_max > e_pad:
        raise ValueError(f"padding too small: need nodes>={n_max} edges>={e_max}")

    node_ids = np.full((k, n_pad), -1, dtype=np.int32)
    node_mask = np.zeros((k, n_pad), dtype=bool)
    owned_mask = np.zeros((k, n_pad), dtype=bool)
    edge_src = np.zeros((k, e_pad), dtype=np.int32)
    edge_dst = np.full((k, e_pad), n_pad - 1, dtype=np.int32)  # park padding
    edge_weight = np.zeros((k, e_pad), dtype=np.float32)
    in_degree = np.zeros((k, n_pad), dtype=np.float32)

    for p in range(k):
        nodes, owned_flags = node_lists[p], owned_lists[p]
        ls, ld, lw = arc_lists[p]
        nn, ne = nodes.shape[0], ls.shape[0]
        node_ids[p, :nn] = nodes
        node_mask[p, :nn] = True
        owned_mask[p, :nn] = owned_flags
        edge_src[p, :ne] = ls
        edge_dst[p, :ne] = ld
        edge_weight[p, :ne] = lw
        np.add.at(in_degree[p], ld, 1.0)

    return PartitionBatch(node_ids=node_ids, node_mask=node_mask,
                          owned_mask=owned_mask, edge_src=edge_src,
                          edge_dst=edge_dst, edge_weight=edge_weight,
                          in_degree=in_degree, n_pad=n_pad, e_pad=e_pad)


@dataclasses.dataclass(frozen=True)
class HaloExchangeSpec:
    """Communication plan for the *synchronized* baseline (per layer).

    For every partition p: which local rows must be fetched from which peer.
    Encoded densely for SPMD: for each p, a [H_pad] list of (peer, peer_local
    row) plus the local halo row it lands in. This is exactly the traffic LF
    eliminates — the roofline collective term of the sync baseline reads it.
    """
    send_rows: np.ndarray   # [k, k, H_pad] int32: rows p sends to q (local idx in p), -1 pad
    recv_rows: np.ndarray   # [k, k, H_pad] int32: halo rows in p filled from q, -1 pad
    h_pad: int


# ---------------------------------------------------------------------------
# Model integration — aggregate the k per-partition GNNs before assembly
# (randomized-partition model aggregation, arxiv 2305.09887; DESIGN.md §12)
# ---------------------------------------------------------------------------
INTEGRATION_KINDS = ("none", "model_avg", "ensemble")


def average_partition_params(params, weights: Optional[np.ndarray] = None):
    """Parameter-average k stacked per-partition models.

    ``params`` is any pytree whose float leaves carry a leading partition
    axis of size k (the layout of ``init_partition_models``). Returns a
    pytree of the SAME shape: the (optionally ``weights``-weighted) mean
    over the partition axis, broadcast back to all k rows — so the result
    drops into every per-partition step/eval function unchanged.

    Averaging k identical replicas is a fixed point (pinned by a hypothesis
    property in tests/test_stale_mode.py)."""
    import jax
    import jax.numpy as jnp
    if weights is None:
        avg = jax.tree.map(
            lambda x: jnp.mean(x.astype(jnp.float32), axis=0), params)
    else:
        w = jnp.asarray(weights, dtype=jnp.float32)
        if w.ndim != 1:
            raise ValueError(f"weights must be 1-D, got shape {w.shape}")
        w = w / jnp.maximum(w.sum(), 1e-12)

        def wavg(x):
            if w.shape[0] != x.shape[0]:
                raise ValueError(
                    f"weights length {w.shape[0]} != partition axis "
                    f"{x.shape[0]}")
            wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
            return jnp.sum(x.astype(jnp.float32) * wb, axis=0)
        avg = jax.tree.map(wavg, params)
    return jax.tree.map(
        lambda a, x: jnp.broadcast_to(a[None], x.shape).astype(x.dtype),
        avg, params)


def integrate_models(params, kind: str = "model_avg",
                     weights: Optional[np.ndarray] = None):
    """Dispatch the parameter-level integration step.

    ``"none"`` returns params untouched; ``"model_avg"`` parameter-averages
    (see :func:`average_partition_params`). ``"ensemble"`` is prediction-
    level and therefore deliberately NOT handled here — embedding averaging
    needs the mode's own forward, see ``repro.gnn.train.apply_integration``.
    """
    if kind not in INTEGRATION_KINDS:
        raise ValueError(
            f"integration kind must be one of {INTEGRATION_KINDS}, "
            f"got {kind!r}")
    if kind == "ensemble":
        raise ValueError(
            "ensemble integration is prediction-level; use "
            "repro.gnn.train.apply_integration with the mode's forward")
    if kind == "none":
        return params
    return average_partition_params(params, weights)


def build_halo_exchange(g: Graph, labels: np.ndarray,
                        batch: PartitionBatch) -> HaloExchangeSpec:
    """Plan per-pair halo transfers for the synchronized baseline (Repli batch)."""
    labels = np.asarray(labels, dtype=np.int64)
    k = batch.k
    # map original node id -> local row per partition
    local_row = {}
    for p in range(k):
        ids = batch.node_ids[p]
        for r, nid in enumerate(ids):
            if nid >= 0:
                local_row[(p, int(nid))] = r
    sends: dict = {(p, q): [] for p in range(k) for q in range(k)}
    recvs: dict = {(p, q): [] for p in range(k) for q in range(k)}
    for p in range(k):
        ids = batch.node_ids[p]
        owned = batch.owned_mask[p]
        valid = batch.node_mask[p]
        for r in range(batch.n_pad):
            if not valid[r] or owned[r]:
                continue
            nid = int(ids[r])
            q = int(labels[nid])        # owner partition
            sends[(q, p)].append(local_row[(q, nid)])
            recvs[(p, q)].append(r)
    h_max = max((len(v) for v in sends.values()), default=1)
    h_pad = max(h_max, 1)
    send_rows = np.full((k, k, h_pad), -1, dtype=np.int32)
    recv_rows = np.full((k, k, h_pad), -1, dtype=np.int32)
    for (p, q), rows in sends.items():
        send_rows[p, q, :len(rows)] = rows
    for (p, q), rows in recvs.items():
        recv_rows[p, q, :len(rows)] = rows
    return HaloExchangeSpec(send_rows=send_rows, recv_rows=recv_rows,
                            h_pad=h_pad)
