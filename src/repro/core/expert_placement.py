"""Beyond-paper: Leiden-Fusion for MoE expert placement.

The paper partitions a *data* graph to minimize training communication. The
same algorithm transfers to expert-parallel MoE serving/training: build the
**expert co-activation graph** (nodes = experts, edge weight = how often two
experts are routed the same token by top-k), partition it with Leiden-Fusion
into one community per model-parallel shard, and place co-activated experts
on the same chip. Tokens whose top-k experts all live on one shard need no
all-to-all hop for dispatch/combine — LF's minimal-edge-cut objective is
exactly minimal cross-shard token traffic.

``placement_cost`` scores a placement by the expected fraction of
(token, expert) assignments that cross shards, so the LF placement can be
compared against the default contiguous split — measured in
EXPERIMENTS.md §Perf and examples/moe_expert_placement.py.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from .fusion import fuse
from .graph import Graph
from .leiden import leiden


def coactivation_graph(expert_idx: np.ndarray, num_experts: int,
                       weights: Optional[np.ndarray] = None) -> Graph:
    """Build the expert co-activation graph.

    expert_idx: [T, K] — the top-k expert ids per token (from the router).
    Edge (a, b) accumulates 1 for every token that routes to both a and b.
    """
    t, k = expert_idx.shape
    srcs, dsts, ws = [], [], []
    for i in range(k):
        for j in range(i + 1, k):
            srcs.append(expert_idx[:, i])
            dsts.append(expert_idx[:, j])
            ws.append(weights if weights is not None else np.ones(t))
    return Graph.from_edges(num_experts, np.concatenate(srcs),
                            np.concatenate(dsts), np.concatenate(ws))


def lf_expert_placement(expert_idx: np.ndarray, num_experts: int,
                        num_shards: int, alpha: float = 0.0,
                        seed: int = 0) -> np.ndarray:
    """Place experts on shards with Leiden-Fusion. Returns shard id per
    expert, exactly balanced when num_experts % num_shards == 0 (required —
    every shard must hold the same number of expert weight slots)."""
    g = coactivation_graph(expert_idx, num_experts)
    per = num_experts // num_shards
    assert per * num_shards == num_experts, (num_experts, num_shards)
    # strict balance: cap at per-shard slot count; LF fusion with tight alpha
    labels = leiden(g, max_community_size=per, seed=seed)
    shard = fuse(g, labels, num_shards, max_part_size=per + 0.5)
    shard = _rebalance(g, shard, num_shards, per)
    return shard


def _rebalance(g: Graph, shard: np.ndarray, num_shards: int, per: int
               ) -> np.ndarray:
    """Move lowest-attachment experts out of overfull shards until exact."""
    shard = shard.copy()
    sizes = np.bincount(shard, minlength=num_shards)
    src_, dst_, w_ = g.arcs()
    while (sizes > per).any():
        over = int(np.argmax(sizes))
        under = int(np.argmin(sizes))
        members = np.where(shard == over)[0]
        # attachment of each member to its own shard
        att = np.zeros(members.shape[0])
        for m, e in enumerate(members):
            nbrs = g.neighbors(int(e))
            wts = g.neighbor_weights(int(e))
            att[m] = wts[shard[nbrs] == over].sum()
        mv = int(members[np.argmin(att)])
        shard[mv] = under
        sizes[over] -= 1
        sizes[under] += 1
    return shard


def placement_cost(expert_idx: np.ndarray, placement: np.ndarray,
                   token_shard: Optional[np.ndarray] = None) -> Dict[str, float]:
    """Fraction of (token, expert) hops that cross shards.

    Without token_shard, tokens are assumed uniformly spread over shards, so
    an assignment to an expert on shard s costs (1 - 1/num_shards) ... the
    comparable quantity between placements is the *pairwise dispersion*: the
    mean number of DISTINCT shards a token's top-k set touches (fewer
    distinct shards = fewer all-to-all partners = less traffic)."""
    t, k = expert_idx.shape
    shards_per_token = np.array(
        [len(set(placement[expert_idx[i]])) for i in range(t)])
    return {
        "mean_shards_per_token": float(shards_per_token.mean()),
        "p90_shards_per_token": float(np.percentile(shards_per_token, 90)),
        "single_shard_frac": float((shards_per_token == 1).mean()),
    }


def contiguous_placement(num_experts: int, num_shards: int) -> np.ndarray:
    """The default (expert id // per-shard) placement used by naive
    expert-parallel sharding of a [E, ...] weight tensor."""
    per = num_experts // num_shards
    return np.arange(num_experts) // per


def apply_placement_to_params(params_moe: dict, placement: np.ndarray
                              ) -> Tuple[dict, np.ndarray]:
    """Reorder the expert axis of the MoE weight stacks so that shard s holds
    experts with placement == s contiguously (then the standard P("model")
    sharding of the E axis realizes the LF placement). Returns (params, perm)
    where perm maps new position -> old expert id; the router output must be
    remapped with argsort(perm)."""
    perm = np.argsort(placement, kind="stable")
    out = dict(params_moe)
    for name in ("w_gate", "w_up", "w_out"):
        if name in out:
            out[name] = out[name][..., perm, :, :] \
                if out[name].ndim == 4 else out[name][perm]
    if "router" in out:
        out["router"] = out["router"][..., perm]
    return out, perm
