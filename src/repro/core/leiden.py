"""Size-capped Leiden community detection (Traag, Waltman & van Eck 2019).

The paper (Definition 1) uses Leiden with a maximum community size
``S = beta * max_part_size``; communities maximize modularity

    Q = 1/(2m) * sum_c (e_c - gamma * K_c^2 / (2m))

subject to |C_i| <= S (size measured in *original* nodes, carried through
aggregation levels via ``Graph.node_weight``).

Implementation: the standard three phases, iterated to a fixed point —
  1. local moving (frontier-batched, modularity-greedy, size-capped),
  2. refinement (each community is re-partitioned into well-connected
     sub-communities; this is the Leiden guarantee that every community is
     connected),
  3. aggregation (quotient graph on the refined partition, with the phase-1
     partition as the starting assignment at the next level).

The local move is fully vectorized (DESIGN.md §10): each sweep gathers the
neighbor labels of every frontier node at once, segment-sums connection
weights per ``(node, community)`` key, picks the best admissible move per
node, resolves conflicts (size cap honored cumulatively, A<->B swaps
suppressed), applies all surviving moves in one shot, and rebuilds the
frontier from the moved nodes' neighborhoods. Sweeps repeat until the
frontier drains. This replaces the former per-node Python queue and is what
makes 100k+-node graphs routine.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro import obs

from .engine import split_components
from .graph import Graph

# Batched sweeps terminate when the frontier drains, when the accepted
# move fraction falls under 1/_MOVE_CUTOFF (the standard Louvain tolerance:
# a long tail of near-zero-gain churn contributes nothing that the next
# aggregation level does not recover), or when the sweep budget runs out.
# The budget keeps total arc-work per local move roughly constant: small
# graphs get up to _MAX_SWEEPS sweeps (full convergence), large graphs a
# handful (multi-level practice — aggregate early, the next, much smaller
# level finishes the job at a fraction of the cost).
_MAX_SWEEPS = 100
_MIN_SWEEPS = 8
_SWEEP_ARC_BUDGET = 24_000_000
_MOVE_CUTOFF = 200
_GAIN_TOL = 1e-12
# Bounded-workspace frontier slicing: one sweep's frontier is processed in
# slices of at most this many arcs whenever the graph is an MmapGraphStore
# OR carries more total arcs than the budget (the aggregation levels above
# a store are in-RAM quotients but can stay nearly as large as the original
# graph — whole-frontier sweeps there would materialize multi-GB transients
# and defeat the RAM budget, DESIGN.md §15). Small in-RAM graphs — every
# tier-1 graph — always use a single slice, the whole frontier at once,
# which keeps that path byte-identical to the pre-GraphStore behavior.
_OOC_BATCH_ARCS = 4_000_000


def _segment_starts(sorted_keys: np.ndarray) -> np.ndarray:
    """Indices where a new key group begins in a sorted key array."""
    return np.flatnonzero(np.r_[True, sorted_keys[1:] != sorted_keys[:-1]])


def _gather_arcs(g: Graph, nodes: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(asrc, adst, aw) — the CSR slices of all given nodes concatenated.

    Thin dispatch onto the GraphStore protocol: both backends implement
    ``gather_arcs`` (the in-RAM one by flat CSR indexing, the mmap one by
    per-chunk reads)."""
    return g.gather_arcs(nodes)


def _frontier_batches(g, nodes: np.ndarray, budget: int) -> list:
    """Split an (ascending) frontier into slices of at most ``budget`` arcs
    (a single over-budget node still gets a slice of its own)."""
    counts = np.asarray(g.indptr[nodes + 1]) - np.asarray(g.indptr[nodes])
    csum = np.cumsum(counts)
    out = []
    start = 0
    while start < nodes.size:
        base = int(csum[start - 1]) if start else 0
        stop = int(np.searchsorted(csum, base + budget, side="right"))
        stop = max(stop, start + 1)
        out.append(nodes[start:stop])
        start = stop
    return out


def _local_move(g: Graph, labels: np.ndarray, comm_size: np.ndarray,
                comm_deg: np.ndarray, max_size: float, two_m: float,
                gamma: float, rng: np.random.Generator,
                fixed_community_of: Optional[np.ndarray] = None) -> bool:
    """Frontier-batched greedy local moving. Mutates labels/comm_size/
    comm_deg.

    ``fixed_community_of``: when refining, node v may only join communities
    within its phase-1 community; pass the phase-1 labels to enforce it.
    Returns True if anything moved.

    Per sweep, for every frontier node the gain of moving v from its
    community cv to a neighboring community c is

        delta(v -> c) = [w(v,c) - gamma*deg_v*K_c/(2m)] -
                        [w(v,cv\\v) - gamma*deg_v*(K_cv-deg_v)/(2m)]

    exactly as in the sequential formulation; what batching changes is only
    *which* greedy sequence is realized (see DESIGN.md §10 for why conflict
    resolution preserves the modularity-greedy semantics).
    """
    n = g.n
    deg = g.degrees()
    node_w = g.node_weight
    S = comm_size.shape[0]              # community id capacity
    # seed-dependent node priority: the deterministic stand-in for the
    # sequential version's random queue order (used as the final tie-break
    # in conflict resolution).
    prio = rng.permutation(n)
    active = np.ones(n, dtype=bool)
    # return hysteresis: the community each node last left. Batched sweeps
    # compute gains against sweep-start state, so a node and its neighbors
    # can keep perceiving a positive gain for undoing each other's moves —
    # banning the direct return (until the node moves somewhere else) makes
    # every period-2 oscillation die out and lets the frontier drain.
    last_left = np.full(n, -1, dtype=np.int64)
    moved_any = False
    fixed = fixed_community_of
    sliced = (getattr(g, "out_of_core", False)
              or g.num_arcs > _OOC_BATCH_ARCS)
    max_sweeps = int(np.clip(_SWEEP_ARC_BUDGET // max(g.num_arcs, 1),
                             _MIN_SWEEPS, _MAX_SWEEPS))
    _empty = np.zeros(0, dtype=np.int64)

    def sweep_slice(nodes: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray, bool]:
        """One frontier slice: gather, score, resolve conflicts, apply the
        surviving moves. Returns (accepted nodes, their targets, whether
        any positive-gain candidate existed). Small in-RAM graphs run
        exactly one slice per sweep (the whole frontier), so the greedy
        sequence there is unchanged; stores and over-budget graphs run
        several, each seeing the previous slice's applied moves — a
        different but equally valid greedy order."""
        nonlocal comm_size, comm_deg
        # ---- gather: connection weight from each frontier node to each
        # neighboring community, via one segment-sum over (node, comm) keys
        asrc, adst, aw = _gather_arcs(g, nodes)
        if asrc.size == 0:
            return _empty, _empty, False
        key = asrc * S + labels[adst]
        order = np.argsort(key, kind="stable")
        skey, sw = key[order], aw[order]
        starts = _segment_starts(skey)
        w_to = np.add.reduceat(sw, starts)
        ukey = skey[starts]
        unode = ukey // S
        ucomm = ukey % S
        cv = labels[unode]
        is_cur = ucomm == cv
        # ---- gains against the slice-start community state
        w_v_cv = np.zeros(n)
        w_v_cv[unode[is_cur]] = w_to[is_cur]
        dv = deg[unode]
        base = w_v_cv[unode] - gamma * dv * (comm_deg[cv] - dv) / two_m
        gain = (w_to - gamma * dv * comm_deg[ucomm] / two_m) - base
        admissible = ~is_cur
        admissible &= comm_size[ucomm] + node_w[unode] <= max_size
        admissible &= ucomm != last_left[unode]
        if fixed is not None:
            admissible &= fixed[ucomm] == fixed[cv]
        gain = np.where(admissible, gain, -np.inf)
        # ---- best admissible move per node: entries are grouped by node
        # and sorted by community id, so a segmented max + first-winner
        # pick gives the best gain with ties going to the smaller community
        nstart = _segment_starts(unode)
        group = np.repeat(np.arange(nstart.size), np.diff(np.r_[nstart,
                                                               unode.size]))
        gmax = np.maximum.reduceat(gain, nstart)
        winner = gain == gmax[group]
        pos = np.where(winner, np.arange(unode.size), unode.size)
        best = np.minimum.reduceat(pos, nstart)
        good = gmax > _GAIN_TOL
        best = best[good]
        mv_node, mv_to, mv_gain = unode[best], ucomm[best], gain[best]
        if mv_node.size == 0:
            return _empty, _empty, False
        mv_from = labels[mv_node]
        # ---- swap guard: when moves A->B and B->A are both pending, the
        # sequential greedy would realize only one of them (whichever ran
        # first) — keep the moves into the smaller community id, drop the
        # mirror, so batched application cannot oscillate on 2-cycles.
        pair = mv_from * S + mv_to
        blocked = np.isin(mv_to * S + mv_from, pair) & (mv_to > mv_from)
        mv_node, mv_to, mv_from = (mv_node[~blocked], mv_to[~blocked],
                                   mv_from[~blocked])
        mv_gain = mv_gain[~blocked]
        if mv_node.size == 0:
            return _empty, _empty, False
        # ---- cap-aware acceptance: per target community, admit movers in
        # gain order while the size cap holds against slice-start sizes
        # (departures are not credited until next slice — conservative, so
        # the cap can never overshoot).
        order2 = np.lexsort((prio[mv_node], -mv_gain, mv_to))
        t, nn, ff = mv_to[order2], mv_node[order2], mv_from[order2]
        w_add = node_w[nn]
        csum = np.cumsum(w_add)
        gstart = _segment_starts(t)
        glen = np.diff(np.r_[gstart, t.size])
        before_group = np.repeat(csum[gstart] - w_add[gstart], glen)
        accept = comm_size[t] + (csum - before_group) <= max_size
        nn, t, ff = nn[accept], t[accept], ff[accept]
        if nn.size == 0:
            return _empty, _empty, True
        # ---- apply the surviving moves in one shot
        labels[nn] = t
        last_left[nn] = ff
        dw, dd = node_w[nn], deg[nn]
        comm_size -= np.bincount(ff, weights=dw, minlength=S)
        comm_size += np.bincount(t, weights=dw, minlength=S)
        comm_deg -= np.bincount(ff, weights=dd, minlength=S)
        comm_deg += np.bincount(t, weights=dd, minlength=S)
        return nn, t, True

    sweeps_ctr = obs.counter("partition.sweeps")
    moves_ctr = obs.counter("partition.moves")
    for _ in range(max_sweeps):
        nodes = np.flatnonzero(active)
        if nodes.size == 0:
            break
        active[nodes] = False
        slices = (_frontier_batches(g, nodes, _OOC_BATCH_ARCS)
                  if sliced else [nodes])
        sweeps_ctr.inc()
        with obs.span("engine.sweep", frontier=int(nodes.size),
                      slices=len(slices)) as sweep_sp:
            moved_nodes, moved_to = [], []
            any_candidates = False
            for sl in slices:
                s_nn, s_t, had = sweep_slice(sl)
                any_candidates |= had
                if s_nn.size:
                    moved_nodes.append(s_nn)
                    moved_to.append(s_t)
            if not any_candidates:
                break
            if not moved_nodes:
                continue
            nn = np.concatenate(moved_nodes) if len(moved_nodes) > 1 \
                else moved_nodes[0]
            t = np.concatenate(moved_to) if len(moved_to) > 1 else moved_to[0]
            moved_any = True
            moves_ctr.inc(int(nn.size))
            sweep_sp.set(moved=int(nn.size))
        if nn.size * _MOVE_CUTOFF < n:
            break
        # ---- next frontier: neighbors of moved nodes that did not end up
        # in the mover's new community (the batched form of the sequential
        # re-queue rule)
        if sliced:
            # stores gather chunk-grouped: present the nodes ascending
            # (activation flags are a set union, so order is irrelevant);
            # slicing also bounds this gather's arc workspace
            order = np.argsort(nn, kind="stable")
            nn, t = nn[order], t[order]
            batches = _frontier_batches(g, nn, _OOC_BATCH_ARCS)
        else:
            batches = [nn]
        pos = 0
        for bn in batches:
            bt = t[pos:pos + bn.size]
            pos += bn.size
            _, mdst, _ = _gather_arcs(g, bn)
            newlab = np.repeat(bt, np.asarray(g.indptr[bn + 1])
                               - np.asarray(g.indptr[bn]))
            active[mdst[labels[mdst] != newlab]] = True
    return moved_any


def _refine(g: Graph, labels: np.ndarray, max_size: float, two_m: float,
            gamma: float, rng: np.random.Generator) -> np.ndarray:
    """Refinement phase: split each community into connected sub-communities.

    Simplified Leiden refinement: start from singletons and run size-capped
    local moving restricted to the phase-1 communities, then split any
    refined community that batched moving left disconnected (one vectorized
    union-find pass) — every refined community is connected, which is the
    guarantee the paper relies on.
    """
    n = g.n
    ref = np.arange(n, dtype=np.int64)
    deg = g.degrees()
    comm_size = g.node_weight.copy()
    comm_deg = deg.copy()
    # fixed_community_of maps *refined community id* (== node id initially)
    # to its phase-1 community.
    _local_move(g, ref, comm_size, comm_deg, max_size, two_m, gamma, rng,
                fixed_community_of=labels)
    # connectivity guarantee + compact ids in one pass
    return split_components(g, ref)


def leiden(g: Graph, max_community_size: Optional[float] = None,
           gamma: float = 1.0, seed: int = 0, max_levels: int = 10
           ) -> np.ndarray:
    """Run size-capped Leiden; returns community labels (n,) int64.

    ``max_community_size`` is measured in original-graph nodes (the paper's
    ``S = beta * max_part_size``). ``None`` = uncapped. ``gamma`` is the
    modularity resolution (the spec grammar's ``resolution=`` field): higher
    values favor more, smaller communities.

    Every returned community is connected: the refinement phase guarantees
    it level by level, and a final vectorized component split enforces it
    unconditionally (a no-op whenever the guarantee already holds).
    """
    if not gamma > 0:
        raise ValueError(f"gamma (resolution) must be > 0, got {gamma}")
    rng = np.random.default_rng(seed)
    two_m = 2.0 * g.m
    if two_m <= 0:
        return np.zeros(g.n, dtype=np.int64)
    cap = float(max_community_size) if max_community_size else np.inf

    level_graph = g
    # mapping from original nodes to current-level nodes
    node_to_level = np.arange(g.n, dtype=np.int64)
    # initial partition for the current level's local move (singletons at L0)
    init = np.arange(g.n, dtype=np.int64)
    final_labels = np.arange(g.n, dtype=np.int64)

    for lvl in range(max_levels):
        n = level_graph.n
        labels = init.copy()
        num_init = int(labels.max()) + 1
        comm_size = np.bincount(labels, weights=level_graph.node_weight,
                                minlength=num_init)
        comm_deg = np.bincount(labels, weights=level_graph.degrees(),
                               minlength=num_init)
        with obs.span("partition.local_move", level=lvl, n=int(n),
                      arcs=int(level_graph.num_arcs)):
            moved = _local_move(level_graph, labels, comm_size, comm_deg,
                                cap, two_m, gamma, rng)
        _, labels = np.unique(labels, return_inverse=True)
        num_comms = int(labels.max()) + 1
        final_labels = labels[node_to_level]
        if not moved or num_comms == n:
            break
        with obs.span("partition.refine", level=lvl, n=int(n)):
            refined = _refine(level_graph, labels, cap, two_m, gamma, rng)
        num_refined = int(refined.max()) + 1
        if num_refined == n:
            # refinement couldn't merge anything: aggregation would be the
            # identity and the next level would repeat this one — stop.
            break
        with obs.span("partition.aggregate", level=lvl, n=int(n),
                      communities=int(num_refined)):
            agg = level_graph.aggregate(refined)
        # phase-1 community of each refined community (refined ⊆ phase-1):
        # the next level starts from the phase-1 partition, per Leiden.
        ref_to_comm = np.zeros(num_refined, dtype=np.int64)
        ref_to_comm[refined] = labels
        init = ref_to_comm
        node_to_level = refined[node_to_level]
        level_graph = agg
    # enforce connectivity on the final labels (no-op when the refinement
    # guarantee held at every level) and compact to 0..k-1
    return split_components(g, final_labels)
