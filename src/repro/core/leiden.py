"""Size-capped Leiden community detection (Traag, Waltman & van Eck 2019).

The paper (Definition 1) uses Leiden with a maximum community size
``S = beta * max_part_size``; communities maximize modularity

    Q = 1/(2m) * sum_c (e_c - gamma * K_c^2 / (2m))

subject to |C_i| <= S (size measured in *original* nodes, carried through
aggregation levels via ``Graph.node_weight``).

Implementation: the standard three phases, iterated to a fixed point —
  1. local moving (queue-based, modularity-greedy, size-capped),
  2. refinement (each community is re-partitioned into well-connected
     sub-communities; this is the Leiden guarantee that every community is
     connected),
  3. aggregation (quotient graph on the refined partition, with the phase-1
     partition as the starting assignment at the next level).

Pure numpy + python loops over the queue; fast enough for the graph sizes in
the benchmarks (the paper itself reports 11.5 s for Leiden on Arxiv with the
reference C library — we are within the same order on the scaled datasets).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .graph import Graph


def _local_move(g: Graph, labels: np.ndarray, comm_size: np.ndarray,
                comm_deg: np.ndarray, max_size: float, two_m: float,
                gamma: float, rng: np.random.Generator,
                fixed_community_of: Optional[np.ndarray] = None) -> bool:
    """Queue-based greedy local moving. Mutates labels/comm_size/comm_deg.

    ``fixed_community_of``: when refining, node v may only join communities
    within its phase-1 community; pass the phase-1 labels to enforce it.
    Returns True if anything moved.
    """
    n = g.n
    deg = g.degrees()
    order = rng.permutation(n)
    in_queue = np.ones(n, dtype=bool)
    queue = list(order)
    head = 0
    moved_any = False
    indptr, indices, ew = g.indptr, g.indices, g.edge_weight
    node_w = g.node_weight
    while head < len(queue):
        v = int(queue[head]); head += 1
        in_queue[v] = False
        cv = int(labels[v])
        # weights from v to each neighboring community
        nbrs = indices[indptr[v]:indptr[v + 1]]
        ws = ew[indptr[v]:indptr[v + 1]]
        if nbrs.size == 0:
            continue
        ncomms = labels[nbrs]
        # accumulate per-community connection weight
        uniq, inv = np.unique(ncomms, return_inverse=True)
        w_to = np.zeros(uniq.shape[0], dtype=np.float64)
        np.add.at(w_to, inv, ws)
        # gain of leaving cv:    (remove v) then (join c)
        # delta(v -> c) = [w(v,c) - gamma*deg_v*K_c/(2m)] -
        #                 [w(v,cv\v) - gamma*deg_v*(K_cv-deg_v)/(2m)]
        w_v_cv = w_to[uniq == cv].sum()
        base = w_v_cv - gamma * deg[v] * (comm_deg[cv] - deg[v]) / two_m
        best_c, best_gain = cv, 0.0
        for i in range(uniq.shape[0]):
            c = int(uniq[i])
            if c == cv:
                continue
            if fixed_community_of is not None and \
                    fixed_community_of[c] != fixed_community_of[cv]:
                continue
            if comm_size[c] + node_w[v] > max_size:
                continue
            gain = (w_to[i] - gamma * deg[v] * comm_deg[c] / two_m) - base
            if gain > best_gain + 1e-12:
                best_gain, best_c = gain, c
        if best_c != cv:
            labels[v] = best_c
            comm_size[cv] -= node_w[v]; comm_size[best_c] += node_w[v]
            comm_deg[cv] -= deg[v]; comm_deg[best_c] += deg[v]
            moved_any = True
            # re-queue neighbors not in best_c
            for u in nbrs[ncomms != best_c]:
                u = int(u)
                if not in_queue[u]:
                    in_queue[u] = True
                    queue.append(u)
    return moved_any


def _refine(g: Graph, labels: np.ndarray, max_size: float, two_m: float,
            gamma: float, rng: np.random.Generator) -> np.ndarray:
    """Refinement phase: split each community into connected sub-communities.

    Simplified Leiden refinement: start from singletons and run size-capped
    local moving restricted to the phase-1 communities. Because a singleton
    only ever merges with a community it has an edge to, every refined
    community is connected — which is the guarantee the paper relies on.
    """
    n = g.n
    ref = np.arange(n, dtype=np.int64)
    deg = g.degrees()
    comm_size = g.node_weight.copy()
    comm_deg = deg.copy()
    # fixed_community_of maps *refined community id* (== node id initially)
    # to its phase-1 community.
    _local_move(g, ref, comm_size, comm_deg, max_size, two_m, gamma, rng,
                fixed_community_of=labels)
    # compact ids
    _, ref = np.unique(ref, return_inverse=True)
    return ref


def leiden(g: Graph, max_community_size: Optional[float] = None,
           gamma: float = 1.0, seed: int = 0, max_levels: int = 10
           ) -> np.ndarray:
    """Run size-capped Leiden; returns community labels (n,) int64.

    ``max_community_size`` is measured in original-graph nodes (the paper's
    ``S = beta * max_part_size``). ``None`` = uncapped. ``gamma`` is the
    modularity resolution (the spec grammar's ``resolution=`` field): higher
    values favor more, smaller communities.
    """
    if not gamma > 0:
        raise ValueError(f"gamma (resolution) must be > 0, got {gamma}")
    rng = np.random.default_rng(seed)
    two_m = 2.0 * g.m
    if two_m <= 0:
        return np.zeros(g.n, dtype=np.int64)
    cap = float(max_community_size) if max_community_size else np.inf

    level_graph = g
    # mapping from original nodes to current-level nodes
    node_to_level = np.arange(g.n, dtype=np.int64)
    # initial partition for the current level's local move (singletons at L0)
    init = np.arange(g.n, dtype=np.int64)
    final_labels = np.arange(g.n, dtype=np.int64)

    for _ in range(max_levels):
        n = level_graph.n
        labels = init.copy()
        num_init = int(labels.max()) + 1
        comm_size = np.zeros(num_init); comm_deg = np.zeros(num_init)
        np.add.at(comm_size, labels, level_graph.node_weight)
        np.add.at(comm_deg, labels, level_graph.degrees())
        moved = _local_move(level_graph, labels, comm_size, comm_deg, cap,
                            two_m, gamma, rng)
        _, labels = np.unique(labels, return_inverse=True)
        num_comms = int(labels.max()) + 1
        final_labels = labels[node_to_level]
        if not moved or num_comms == n:
            break
        refined = _refine(level_graph, labels, cap, two_m, gamma, rng)
        num_refined = int(refined.max()) + 1
        if num_refined == n:
            # refinement couldn't merge anything: aggregation would be the
            # identity and the next level would repeat this one — stop.
            break
        agg = level_graph.aggregate(refined)
        # phase-1 community of each refined community (refined ⊆ phase-1):
        # the next level starts from the phase-1 partition, per Leiden.
        ref_to_comm = np.zeros(num_refined, dtype=np.int64)
        ref_to_comm[refined] = labels
        init = ref_to_comm
        node_to_level = refined[node_to_level]
        level_graph = agg
    # compact final labels
    _, out = np.unique(final_labels, return_inverse=True)
    return out.astype(np.int64)
