"""Open partitioner registry — the heart of Partitioner API v2 (DESIGN.md §9).

A *partitioner* is a named, typed, capability-tagged algorithm that maps
``(Graph, k, seed, config) -> labels``. Registration is open: any module can
add a method with the :func:`register_partitioner` decorator and it becomes
selectable everywhere a spec string is accepted (``PipelineConfig.method``,
the CLI ``--method`` flag, the benchmarks, the artifact cache):

    @register_partitioner("spectral", config=SpectralConfig,
                          capabilities=Capabilities(balanced=True))
    def spectral(g, k, seed, cfg):
        ...

Three ideas live here:

* :class:`Capabilities` — declarative flags (connectivity-guaranteed,
  balanced, deterministic) that tests and the pipeline assert against
  instead of hardcoding per-method knowledge.
* :class:`Partitioner` — the structural protocol every registry entry
  satisfies; :class:`RegisteredPartitioner` is the concrete record.
* :class:`FusionConfig` — the config of the ``+f`` combinator (paper §5.4),
  which composes over *any* registered base method; see
  :mod:`repro.core.spec` for the grammar and execution.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Protocol, Type, \
    runtime_checkable

import numpy as np

from .graph import Graph

__all__ = ["Capabilities", "FusionConfig", "NullConfig", "Partitioner",
           "RegisteredPartitioner", "register_partitioner",
           "unregister_partitioner", "registered_partitioners", "get_entry"]


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What a partitioner guarantees about its output (for a connected
    input graph). The pipeline and tests assert against these flags."""
    connectivity_guaranteed: bool = False   # every partition is 1 component
    balanced: bool = False                  # sizes bounded by a slack factor
    deterministic: bool = True              # same (g, k, seed, cfg) -> same labels

    def describe(self) -> str:
        flags = [("connectivity", self.connectivity_guaranteed),
                 ("balanced", self.balanced),
                 ("deterministic", self.deterministic)]
        on = [name for name, v in flags if v]
        return "|".join(on) if on else "-"


@dataclasses.dataclass(frozen=True)
class NullConfig:
    """Config of a partitioner with no hyperparameters."""


@dataclasses.dataclass(frozen=True)
class FusionConfig:
    """Config of the ``+f`` combinator: run the base method, split every
    partition into its connected components, fuse back down to k (paper
    §5.4). ``base_k`` optionally gives the base method a different target
    partition count than the final k."""
    alpha: float = dataclasses.field(
        default=0.05, metadata={"help": "balance slack: max part size is "
                                        "(n/k)*(1+alpha)"})
    base_k: Optional[int] = dataclasses.field(
        default=None, metadata={"help": "k handed to the base method "
                                        "(default: the final k)"})

    def __post_init__(self):
        if not (self.alpha >= 0.0):
            raise ValueError(f"alpha must be >= 0, got {self.alpha}")
        if self.base_k is not None and self.base_k < 1:
            raise ValueError(f"base_k must be >= 1, got {self.base_k}")


@runtime_checkable
class Partitioner(Protocol):
    """Structural protocol of a registry entry."""
    name: str
    config_type: Type[Any]
    capabilities: Capabilities

    def partition(self, g: Graph, k: int, seed: int = 0,
                  config: Optional[Any] = None):
        """Run the method; returns a :class:`repro.core.spec.PartitionResult`."""
        ...


@dataclasses.dataclass(frozen=True)
class RegisteredPartitioner:
    """One registry entry: the function plus its typed config and flags."""
    name: str
    fn: Callable[[Graph, int, int, Any], np.ndarray]
    config_type: Type[Any]
    capabilities: Capabilities
    doc: str = ""

    def partition(self, g: Graph, k: int, seed: int = 0,
                  config: Optional[Any] = None):
        from .spec import PartitionerSpec
        cfg = self.config_type() if config is None else config
        if not isinstance(cfg, self.config_type):
            raise TypeError(f"partitioner {self.name!r} expects a "
                            f"{self.config_type.__name__}, got "
                            f"{type(cfg).__name__}")
        return PartitionerSpec(method=self.name, config=cfg).partition(
            g, k, seed=seed)


_REGISTRY: Dict[str, RegisteredPartitioner] = {}


def register_partitioner(name: str, *, config: Type[Any] = NullConfig,
                         capabilities: Capabilities = Capabilities(),
                         doc: str = "", overwrite: bool = False):
    """Decorator: register ``fn(g, k, seed, cfg) -> labels`` under ``name``."""
    key = name.lower().replace("-", "_")
    if not dataclasses.is_dataclass(config):
        raise TypeError(f"config for {name!r} must be a dataclass, "
                        f"got {config!r}")

    def deco(fn):
        if key in _REGISTRY and not overwrite:
            raise ValueError(f"partitioner {key!r} already registered; "
                             f"pass overwrite=True to replace it")
        _REGISTRY[key] = RegisteredPartitioner(
            name=key, fn=fn, config_type=config, capabilities=capabilities,
            doc=doc or (fn.__doc__ or "").strip().split("\n")[0])
        return fn
    return deco


def unregister_partitioner(name: str) -> None:
    _REGISTRY.pop(name.lower().replace("-", "_"), None)


def registered_partitioners() -> Dict[str, RegisteredPartitioner]:
    """Snapshot of the registry (name -> entry), sorted by name."""
    return {k: _REGISTRY[k] for k in sorted(_REGISTRY)}


def get_entry(name: str) -> RegisteredPartitioner:
    key = name.lower().replace("-", "_")
    try:
        return _REGISTRY[key]
    except KeyError:
        raise ValueError(f"unknown partitioner {name!r}; available: "
                         f"{sorted(_REGISTRY)}") from None
