"""Out-of-core graph storage — the memory-mapped chunked CSR bundle.

The paper partitions the whole graph centrally (§5 Setup) and until this
module every layer of the repro inherited that assumption: the full CSR in
RAM, edge lists materialized at once, monolithic npz artifacts. The
DGL/GraphStorm answer (SNIPPETS §3) is a chunked on-disk layout —
``node_map``/``edge_map`` manifests plus per-chunk data files — and this
module is our version of it (DESIGN.md §15):

    <dir>/
      manifest.json            # version, n, num_arcs, total_weight,
                               # node_map/edge_map (per-chunk row/arc
                               # ranges), per-file sha256, fingerprint
      indptr.npy               # (n+1,) int64 GLOBAL row pointers
      node_weight.npy          # optional (absent = all ones)
      self_weight.npy          # optional (absent = all zeros)
      chunks/00000.indices.npy # int32 neighbor ids of rows in chunk 0
      chunks/00000.weights.npy # float64 arc weights of chunk 0
      chunks/00001.indices.npy
      ...

The global ``indptr`` is O(n) and deliberately lives in one file: node-sized
arrays are the RAM budget we *do* allow (8 MB per 10^6 nodes), arc-sized
arrays are the ones that must stay on disk. Chunk files are opened with
``np.load(mmap_mode="r")`` so a chunk's pages enter RAM only as they are
read and the OS may evict them at will.

Consumers never call ``arcs()`` on a store — it raises, on purpose, so an
accidental whole-graph materialization fails loudly instead of silently
blowing the RAM budget. Everything community-shaped goes through
``iter_csr_chunks()`` (sequential sweeps: quotient graphs, connected
components, partition metrics, batch assembly) or ``gather_arcs(nodes)``
(random row access: the Leiden frontier), both of which the in-RAM
:class:`~repro.core.graph.Graph` also implements — the ``GraphStore``
protocol is the seam, and the engine is written against it.

Writes are atomic at directory granularity: everything lands in a
``<dir>.tmp-*`` sibling which is ``os.replace``d into place, so a crashed
build can never leave a half-written bundle that later loads. The manifest
carries a content fingerprint (sha256 over n/num_arcs/chunk maps/per-file
hashes); :meth:`MmapGraphStore.load` re-derives it from the manifest and
hard-errors on mismatch, and ``verify=True`` additionally re-hashes every
data file.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs

from .engine import ArcChunk, connected_components_chunks

__all__ = ["STORE_FORMAT_VERSION", "GraphStoreError",
           "GraphStoreIntegrityError", "MmapGraphStore", "atomic_directory",
           "build_store_from_edge_batches", "store_from_graph"]

STORE_FORMAT_VERSION = 1
MANIFEST = "manifest.json"

# Default target arcs per chunk: ~4M arcs -> ~16 MB of int32 indices +
# ~32 MB of float64 weights resident per chunk while sweeping.
DEFAULT_CHUNK_ARCS = 4_000_000


class GraphStoreError(RuntimeError):
    """Malformed/unusable graph-store bundle."""


class GraphStoreIntegrityError(GraphStoreError):
    """Manifest fingerprint or file hash does not match the bundle contents.

    Deliberately a hard error, never a silent fallback: a store that fails
    integrity must not be partitioned or trained on (mirrors the serving
    bundle's ``StaleServingArtifact`` contract, DESIGN.md §13)."""


# ---------------------------------------------------------------------------
# atomic directory writes
# ---------------------------------------------------------------------------

class atomic_directory:
    """``with atomic_directory(final) as tmp: ...`` — populate ``tmp``, and
    on clean exit it is renamed to ``final`` in one ``os.replace``. On error
    the temp tree is deleted and ``final`` is untouched. A pre-existing
    ``final`` is replaced only after the new tree is fully written."""

    def __init__(self, final_path: str):
        self.final = os.path.abspath(final_path)
        self.tmp: Optional[str] = None

    def __enter__(self) -> str:
        parent = os.path.dirname(self.final) or "."
        os.makedirs(parent, exist_ok=True)
        self.tmp = tempfile.mkdtemp(
            dir=parent, prefix=os.path.basename(self.final) + ".tmp-")
        return self.tmp

    def __exit__(self, exc_type, exc, tb) -> None:
        assert self.tmp is not None
        if exc_type is not None:
            shutil.rmtree(self.tmp, ignore_errors=True)
            return
        if os.path.isdir(self.final):
            # replace an existing bundle: move it aside first so the final
            # rename is still atomic, then drop the old tree.
            old = self.tmp + ".old"
            os.replace(self.final, old)
            os.replace(self.tmp, self.final)
            shutil.rmtree(old, ignore_errors=True)
        else:
            os.replace(self.tmp, self.final)


def _sha256_file(path: str, bufsize: int = 1 << 22) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            blk = f.read(bufsize)
            if not blk:
                return h.hexdigest()
            h.update(blk)


def _fingerprint_from(manifest: dict) -> str:
    """The content fingerprint: a digest over the structural fields and the
    per-file hashes (NOT over the stored fingerprint itself)."""
    payload = {k: manifest[k] for k in
               ("format", "version", "n", "num_arcs", "total_weight",
                "node_map", "edge_map", "files")}
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def _save_npy(root: str, rel: str, arr: np.ndarray, files: dict) -> None:
    path = os.path.join(root, rel)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.save(path, arr)
    files[rel] = _sha256_file(path)


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

class MmapGraphStore:
    """A read-only, memory-mapped, chunked-CSR undirected graph.

    Satisfies the same structural protocol the engine consumes from
    :class:`~repro.core.graph.Graph` (``n``/``num_arcs``/``m``/
    ``node_weight``/``self_weight``/``indptr``/``degrees``/
    ``iter_csr_chunks``/``gather_arcs``/``aggregate``/
    ``connected_components``) — but ``out_of_core`` is True and ``arcs()``
    raises instead of materializing the whole arc list.
    """

    out_of_core = True

    def __init__(self, root: str, manifest: dict):
        self.root = root
        self.manifest = manifest
        self.n = int(manifest["n"])
        self.num_arcs = int(manifest["num_arcs"])
        self._total_weight = float(manifest["total_weight"])
        # node_map/edge_map: per-chunk [start, stop) row / arc ranges
        # (DGL's node_map/edge_map analogue for a single-machine bundle).
        self.node_map = [tuple(map(int, r)) for r in manifest["node_map"]]
        self.edge_map = [tuple(map(int, r)) for r in manifest["edge_map"]]
        self.fingerprint = manifest["fingerprint"]
        self.indptr = np.load(os.path.join(root, "indptr.npy"),
                              mmap_mode="r")
        nw_path = os.path.join(root, "node_weight.npy")
        self._node_weight = (np.load(nw_path, mmap_mode="r")
                             if os.path.exists(nw_path) else None)
        sw_path = os.path.join(root, "self_weight.npy")
        self._self_weight = (np.load(sw_path, mmap_mode="r")
                             if os.path.exists(sw_path) else None)
        self._degrees: Optional[np.ndarray] = None

    # ----- load/verify -----------------------------------------------------
    @classmethod
    def load(cls, root: str, verify: bool = False) -> "MmapGraphStore":
        """Open a bundle. Always re-derives the manifest fingerprint from
        the manifest body and hard-errors on mismatch; ``verify=True``
        additionally re-hashes every data file against the manifest."""
        root = os.path.abspath(os.path.expanduser(root))
        mpath = os.path.join(root, MANIFEST)
        if not os.path.exists(mpath):
            raise GraphStoreError(f"no graph-store manifest at {mpath}")
        with open(mpath) as f:
            manifest = json.load(f)
        if manifest.get("format") != "repro-mmap-csr":
            raise GraphStoreError(
                f"{mpath}: not a repro-mmap-csr bundle "
                f"(format={manifest.get('format')!r})")
        if int(manifest.get("version", -1)) > STORE_FORMAT_VERSION:
            raise GraphStoreError(
                f"{mpath}: bundle format v{manifest['version']} is newer "
                f"than this reader (v{STORE_FORMAT_VERSION})")
        derived = _fingerprint_from(manifest)
        if derived != manifest.get("fingerprint"):
            raise GraphStoreIntegrityError(
                f"{root}: manifest fingerprint mismatch "
                f"(stored {manifest.get('fingerprint')!r:.20}..., derived "
                f"{derived[:16]}...) — the bundle was tampered with or "
                f"half-written; rebuild it")
        for rel in manifest["files"]:
            if not os.path.exists(os.path.join(root, rel)):
                raise GraphStoreError(f"{root}: missing data file {rel}")
        if verify:
            for rel, want in manifest["files"].items():
                got = _sha256_file(os.path.join(root, rel))
                if got != want:
                    raise GraphStoreIntegrityError(
                        f"{root}: content hash mismatch for {rel} "
                        f"(manifest {want[:16]}..., file {got[:16]}...)")
        return cls(root, manifest)

    # ----- basic accessors (Graph-compatible) -------------------------------
    @property
    def m(self) -> float:
        """Total undirected edge weight (self-loops included)."""
        return self._total_weight

    @property
    def node_weight(self) -> np.ndarray:
        if self._node_weight is None:
            self._node_weight = np.ones(self.n, dtype=np.float64)
        return self._node_weight

    @property
    def self_weight(self) -> np.ndarray:
        # Graph's zero-length default means "all zeros"; keep the contract.
        if self._self_weight is None:
            return np.zeros(0)
        return self._self_weight

    @property
    def num_chunks(self) -> int:
        return len(self.node_map)

    def degrees(self) -> np.ndarray:
        """Weighted degree per node, computed in one streaming pass and
        cached (O(n) RAM)."""
        if self._degrees is None:
            out = np.zeros(self.n, dtype=np.float64)
            sw = self.self_weight
            if sw.shape[0] == self.n:
                out += 2.0 * np.asarray(sw, dtype=np.float64)
            for ch in self.iter_csr_chunks():
                rows = ch.row_stop - ch.row_start
                counts = np.diff(self.indptr[ch.row_start:ch.row_stop + 1])
                local = np.repeat(np.arange(rows, dtype=np.int64), counts)
                out[ch.row_start:ch.row_stop] += np.bincount(
                    local, weights=ch.weight, minlength=rows)
            self._degrees = out
        return self._degrees

    def arcs(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        raise GraphStoreError(
            "MmapGraphStore.arcs() would materialize the whole arc list in "
            "RAM — iterate iter_csr_chunks() or use gather_arcs(nodes) "
            "instead (the out-of-core contract, DESIGN.md §15)")

    # ----- chunk access -----------------------------------------------------
    def _chunk_arrays(self, c: int) -> Tuple[np.ndarray, np.ndarray]:
        base = os.path.join(self.root, "chunks", f"{c:05d}")
        idx = np.load(base + ".indices.npy", mmap_mode="r")
        wgt = np.load(base + ".weights.npy", mmap_mode="r")
        return idx, wgt

    def iter_csr_chunks(self) -> Iterator[ArcChunk]:
        """Yield every chunk in row order. ``src`` is reconstructed from the
        global indptr (int64), ``dst``/``weight`` are memory-mapped views —
        resident RAM is one chunk's worth at a time.

        Each chunk is yielded under a ``graphstore.chunk`` span covering
        the mmap load *and* the consumer's processing of that chunk; the
        byte counter tracks what a full sweep actually pulls through RAM."""
        chunks_ctr = obs.counter("graphstore.chunks")
        bytes_ctr = obs.counter("graphstore.chunk_bytes")
        for c, ((r0, r1), (a0, a1)) in enumerate(
                zip(self.node_map, self.edge_map)):
            with obs.span("graphstore.chunk", chunk=c, rows=r1 - r0,
                          arcs=a1 - a0, backend="mmap"):
                idx, wgt = self._chunk_arrays(c)
                counts = np.diff(self.indptr[r0:r1 + 1])
                src = np.repeat(np.arange(r0, r1, dtype=np.int64), counts)
                chunks_ctr.inc()
                bytes_ctr.inc(int(src.nbytes + idx.nbytes + wgt.nbytes))
                yield ArcChunk(row_start=r0, row_stop=r1, arc_start=a0,
                               arc_stop=a1, src=src,
                               dst=np.asarray(idx, dtype=np.int64),
                               weight=np.asarray(wgt, dtype=np.float64))

    def gather_arcs(self, nodes: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(asrc, adst, aw) of every arc of ``nodes`` (ascending node ids):
        the random-row-access half of the protocol, used by the Leiden
        frontier. Rows are grouped per chunk so each chunk file is touched
        at most once per call."""
        nodes = np.asarray(nodes, dtype=np.int64)
        obs.counter("graphstore.gather_calls").inc()
        obs.counter("graphstore.gather_rows").inc(int(nodes.size))
        if nodes.size == 0:
            z = np.zeros(0, dtype=np.int64)
            return z, z.copy(), np.zeros(0, dtype=np.float64)
        starts = np.array([r0 for r0, _ in self.node_map], dtype=np.int64)
        which = np.searchsorted(starts, nodes, side="right") - 1
        out_s: List[np.ndarray] = []
        out_d: List[np.ndarray] = []
        out_w: List[np.ndarray] = []
        # nodes ascending -> chunk ids non-decreasing -> contiguous runs
        run_starts = np.flatnonzero(np.r_[True, which[1:] != which[:-1]])
        run_stops = np.r_[run_starts[1:], which.size]
        obs.counter("graphstore.gather_chunks_touched").inc(
            int(run_starts.size))
        for lo, hi in zip(run_starts, run_stops):
            c = int(which[lo])
            sub = nodes[lo:hi]
            idx, wgt = self._chunk_arrays(c)
            a0 = self.edge_map[c][0]
            counts = (self.indptr[sub + 1] - self.indptr[sub]).astype(
                np.int64)
            total = int(counts.sum())
            if total == 0:
                continue
            stops = np.cumsum(counts)
            flat = (np.arange(total, dtype=np.int64)
                    - np.repeat(stops - counts, counts)
                    + np.repeat(self.indptr[sub] - a0, counts))
            out_s.append(np.repeat(sub, counts))
            out_d.append(idx[flat].astype(np.int64))
            out_w.append(np.asarray(wgt[flat], dtype=np.float64))
        if not out_s:
            z = np.zeros(0, dtype=np.int64)
            return z, z.copy(), np.zeros(0, dtype=np.float64)
        return (np.concatenate(out_s), np.concatenate(out_d),
                np.concatenate(out_w))

    # ----- structure queries (Graph-compatible) -----------------------------
    def connected_components(self, mask: Optional[np.ndarray] = None
                             ) -> np.ndarray:
        return connected_components_chunks(
            self.n, lambda: ((ch.src, ch.dst)
                             for ch in self.iter_csr_chunks()), mask=mask)

    def num_components(self, mask: Optional[np.ndarray] = None) -> int:
        comp = self.connected_components(mask)
        return int(comp.max() + 1) if (comp >= 0).any() else 0

    def aggregate(self, labels: np.ndarray):
        """Quotient graph as an in-RAM :class:`Graph` — the coarsen step of
        the coarsen→partition→refine path. The quotient must fit in RAM;
        that is the contract (DESIGN.md §15 RAM-budget math)."""
        from .engine import quotient_edges
        from .graph import Graph
        q = quotient_edges(self, labels)
        return Graph(n=q.k, indptr=q.indptr(),
                     indices=q.dst.astype(np.int32), edge_weight=q.weight,
                     node_weight=q.node_weight, self_weight=q.intra)

    def __repr__(self) -> str:
        return (f"MmapGraphStore(n={self.n}, num_arcs={self.num_arcs}, "
                f"chunks={self.num_chunks}, root={self.root!r})")


# ---------------------------------------------------------------------------
# writers
# ---------------------------------------------------------------------------

def _write_bundle(root: str, n: int,
                  chunk_rows: Sequence[Tuple[int, int]],
                  chunk_payloads: Iterable[Tuple[np.ndarray, np.ndarray]],
                  node_weight: Optional[np.ndarray],
                  self_weight: Optional[np.ndarray],
                  extra_self_weight_total: float = 0.0) -> str:
    """Write a bundle from per-chunk ``(local_indptr, indices, weights)``
    payloads (consumed lazily, in chunk order). Returns the final root
    path."""
    with atomic_directory(root) as tmp:
        files: dict = {}
        indptr = np.zeros(n + 1, dtype=np.int64)
        node_map: List[Tuple[int, int]] = []
        edge_map: List[Tuple[int, int]] = []
        arc_base = 0
        total_w = 0.0
        for (r0, r1), (local_indptr, idx, wgt) in zip(
                chunk_rows, chunk_payloads):
            c = len(node_map)
            idx = np.ascontiguousarray(idx, dtype=np.int32)
            wgt = np.ascontiguousarray(wgt, dtype=np.float64)
            _save_npy(tmp, os.path.join("chunks", f"{c:05d}.indices.npy"),
                      idx, files)
            _save_npy(tmp, os.path.join("chunks", f"{c:05d}.weights.npy"),
                      wgt, files)
            indptr[r0 + 1:r1 + 1] = arc_base + local_indptr[1:]
            node_map.append((int(r0), int(r1)))
            edge_map.append((arc_base, arc_base + idx.shape[0]))
            arc_base += idx.shape[0]
            total_w += float(wgt.sum())
        _save_npy(tmp, "indptr.npy", indptr, files)
        if node_weight is not None:
            _save_npy(tmp, "node_weight.npy",
                      np.ascontiguousarray(node_weight, np.float64), files)
        sw_total = extra_self_weight_total
        if self_weight is not None and np.asarray(self_weight).shape[0]:
            _save_npy(tmp, "self_weight.npy",
                      np.ascontiguousarray(self_weight, np.float64), files)
            sw_total = float(np.asarray(self_weight, np.float64).sum())
        manifest = {
            "format": "repro-mmap-csr",
            "version": STORE_FORMAT_VERSION,
            "n": int(n),
            "num_arcs": int(arc_base),
            # m convention matches Graph.m: arcs are double-counted, plus
            # full self-loop weight once.
            "total_weight": total_w / 2.0 + sw_total,
            "node_map": [list(r) for r in node_map],
            "edge_map": [list(r) for r in edge_map],
            "files": files,
        }
        manifest["fingerprint"] = _fingerprint_from(manifest)
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
    return root


def _chunk_row_ranges(n: int, indptr: np.ndarray,
                      chunk_arcs: int) -> List[Tuple[int, int]]:
    """Row ranges so each chunk holds <= chunk_arcs arcs (single over-wide
    rows get a chunk of their own)."""
    ranges: List[Tuple[int, int]] = []
    r0 = 0
    while r0 < n:
        r1 = int(np.searchsorted(indptr, indptr[r0] + chunk_arcs,
                                 side="right")) - 1
        r1 = min(max(r1, r0 + 1), n)
        ranges.append((r0, r1))
        r0 = r1
    return ranges or [(0, n)]


def store_from_graph(g, root: str,
                     chunk_arcs: int = DEFAULT_CHUNK_ARCS
                     ) -> MmapGraphStore:
    """Copy an in-RAM :class:`Graph` to a chunked mmap bundle."""
    rows = _chunk_row_ranges(g.n, g.indptr, chunk_arcs)

    def payloads():
        for r0, r1 in rows:
            a0, a1 = int(g.indptr[r0]), int(g.indptr[r1])
            local = (g.indptr[r0:r1 + 1] - a0).astype(np.int64)
            yield local, g.indices[a0:a1], g.edge_weight[a0:a1]

    # all-zero self weights / all-ones node weights are the defaults; skip
    # the files (zeros(0) and zeros(n) spell the same "no self-loops")
    sw = g.self_weight if (g.self_weight.shape[0] == g.n
                           and g.self_weight.any()) else None
    nw = None if np.all(g.node_weight == 1.0) else g.node_weight
    _write_bundle(root, g.n, rows, payloads(), nw, sw)
    return MmapGraphStore.load(root)


# ---------------------------------------------------------------------------
# the external-memory CSR builder (streamed edge batches -> bundle)
# ---------------------------------------------------------------------------

_ARC_DTYPE = np.dtype([("src", np.int64), ("dst", np.int64),
                       ("w", np.float64)])


class _ArcBuckets:
    """Pass-1 scratch: per-chunk append-only arc files, bucketed by the
    (fixed, id-range) chunk of each arc's source row."""

    def __init__(self, workdir: str, n: int, num_chunks: int):
        self.n = n
        self.num_chunks = max(int(num_chunks), 1)
        self.rows_per_chunk = -(-n // self.num_chunks)   # ceil
        self.paths = [os.path.join(workdir, f"bucket{c:05d}.bin")
                      for c in range(self.num_chunks)]
        self.handles = [open(p, "ab") for p in self.paths]

    def chunk_of(self, rows: np.ndarray) -> np.ndarray:
        return rows // self.rows_per_chunk

    def add_arcs(self, src: np.ndarray, dst: np.ndarray,
                 w: np.ndarray) -> None:
        """Append directed arcs (already symmetrized by the caller)."""
        rec = np.empty(src.shape[0], dtype=_ARC_DTYPE)
        rec["src"], rec["dst"], rec["w"] = src, dst, w
        which = self.chunk_of(src)
        order = np.argsort(which, kind="stable")
        rec, which = rec[order], which[order]
        starts = np.flatnonzero(np.r_[True, which[1:] != which[:-1]])
        stops = np.r_[starts[1:], which.size]
        for lo, hi in zip(starts, stops):
            self.handles[int(which[lo])].write(rec[lo:hi].tobytes())

    def add_edges(self, src: np.ndarray, dst: np.ndarray,
                  w: Optional[np.ndarray] = None) -> None:
        """Append undirected edges: drops self-loops, writes both arc
        directions (the Graph.from_edges symmetrization, streamed)."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if w is None:
            w = np.ones(src.shape[0], dtype=np.float64)
        keep = src != dst
        src, dst, w = src[keep], dst[keep], w[keep]
        if src.size == 0:
            return
        self.add_arcs(np.concatenate([src, dst]),
                      np.concatenate([dst, src]),
                      np.concatenate([w, w]))

    def iter_bucket_arcs(self) -> Iterator[np.ndarray]:
        for p in self.paths:
            yield np.fromfile(p, dtype=_ARC_DTYPE)

    def close(self) -> None:
        for h in self.handles:
            h.close()


def build_store_from_edge_batches(
        root: str, n: int,
        edge_batches: Iterable[Tuple[np.ndarray, np.ndarray]],
        est_arcs: Optional[int] = None,
        chunk_arcs: int = DEFAULT_CHUNK_ARCS,
        ensure_connected: bool = True,
        connect_rng: Optional[np.random.Generator] = None,
        workdir: Optional[str] = None) -> MmapGraphStore:
    """Build a chunked CSR bundle from streamed (src, dst) edge batches
    without ever materializing the full edge list.

    ``edge_batches`` is consumed exactly once (a generator is fine — the
    rng state a streamed dataset threads through its batches stays in step
    with the in-RAM generation it mirrors). ``est_arcs`` sizes the chunk
    grid (~2x the total edge count; it only controls chunk granularity,
    never correctness — omitted means one chunk per ``chunk_arcs`` rows'
    worth assuming the default arxiv-like average degree).

    Three passes, each bounded by one chunk of arcs in RAM:

    1. **bucket** — every batch is symmetrized (self-loops dropped, both
       arc directions written) and appended to the scratch file of its
       source row's chunk. Chunks are fixed node-id ranges, so an arc's
       bucket is known before degrees are.
    2. **connect** (optional) — a streamed union-find over the scratch
       buckets (:func:`connected_components_chunks`); one chain edge per
       extra component is appended so the bundle is connected. With
       ``connect_rng`` the chain endpoints replicate the in-RAM
       ``_ensure_connected`` draws exactly (same rng, same component
       numbering, same ``choice`` calls — so a streamed build is
       CSR-identical to ``Graph.from_edges`` + ``_ensure_connected``);
       without it, smallest members are chained deterministically.
    3. **finalize** — per bucket: sort by (src, dst), merge duplicate arcs
       by summing weights, emit the chunk's indices/weights files; the
       global indptr accumulates per-row counts. All arcs of a row live in
       that row's one bucket, so per-bucket dedup is global dedup.
    """
    work_ctx = tempfile.TemporaryDirectory(
        dir=workdir or os.path.dirname(os.path.abspath(root)) or ".",
        prefix=".graphstore-build-")
    with work_ctx as work:
        if est_arcs is None:
            est_arcs = int(n * 2 * 13.8)
        num_chunks = max(1, -(-int(est_arcs) // chunk_arcs))
        buckets = _ArcBuckets(work, n, num_chunks)
        for src, dst in edge_batches:
            buckets.add_edges(src, dst)
        buckets.close()

        if ensure_connected:
            def arc_chunks():
                for rec in buckets.iter_bucket_arcs():
                    yield rec["src"], rec["dst"]
            comp = connected_components_chunks(n, arc_chunks)
            k = int(comp.max()) + 1 if comp.size else 0
            if k > 1:
                if connect_rng is not None:
                    # replicate _ensure_connected's draws: a random member
                    # of each extra component chained to a random member
                    # of component 0, in component order.
                    reps = [np.where(comp == c)[0] for c in range(k)]
                    extra_src = np.array(
                        [connect_rng.choice(reps[c]) for c in range(1, k)],
                        dtype=np.int64)
                    extra_dst = connect_rng.choice(
                        reps[0], size=k - 1).astype(np.int64)
                else:
                    # deterministic: smallest member of each extra
                    # component chained to the overall smallest node
                    # (components are numbered by smallest member, so the
                    # first occurrence per component id is that member).
                    order = np.argsort(comp, kind="stable")
                    cs = comp[order]
                    starts = np.flatnonzero(np.r_[True, cs[1:] != cs[:-1]])
                    reps_arr = order[starts]
                    extra_src = reps_arr[1:]
                    extra_dst = np.full(k - 1, reps_arr[0], dtype=np.int64)
                handles = [open(p, "ab") for p in buckets.paths]
                rec = np.empty(2 * (k - 1), dtype=_ARC_DTYPE)
                rec["src"] = np.concatenate([extra_src, extra_dst])
                rec["dst"] = np.concatenate([extra_dst, extra_src])
                rec["w"] = 1.0
                for r in rec:
                    handles[int(r["src"] // buckets.rows_per_chunk)].write(
                        r.tobytes())
                for h in handles:
                    h.close()

        rows = [(c * buckets.rows_per_chunk,
                 min((c + 1) * buckets.rows_per_chunk, n))
                for c in range(buckets.num_chunks)]
        rows = [r for r in rows if r[0] < r[1]]

        def payloads():
            for (r0, r1), path in zip(rows, buckets.paths):
                rec = np.fromfile(path, dtype=_ARC_DTYPE)
                src, dst, w = rec["src"], rec["dst"], rec["w"]
                # sort + merge duplicates (sum weights) — the streamed form
                # of Graph.from_edges(dedup=True); all arcs of a row live
                # in this one bucket, so per-bucket dedup is global dedup.
                key = src * n + dst
                order = np.argsort(key, kind="stable")
                key, src, w = key[order], src[order], w[order]
                starts = np.flatnonzero(np.r_[True, key[1:] != key[:-1]])
                w = np.add.reduceat(w, starts) if key.size else w
                key = key[starts] if key.size else key
                src = src[starts] if key.size else src
                dst = key - src * n
                counts = np.bincount(src - r0, minlength=r1 - r0)
                local = np.zeros(r1 - r0 + 1, dtype=np.int64)
                np.cumsum(counts, out=local[1:])
                yield local, dst, w

        _write_bundle(root, n, rows, payloads(), None, None)
    return MmapGraphStore.load(root)
