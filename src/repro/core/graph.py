"""Graph data structures for the Leiden-Fusion pipeline.

Everything partition-side is plain numpy (the paper runs partitioning on one
CPU in a centralized way; see §5 Setup). The JAX side consumes the padded CSR
buffers produced by :mod:`repro.core.assemble`.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Iterable, Iterator, Optional, Tuple

import numpy as np

from repro import obs

from . import engine


@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected graph in CSR form.

    Edges are stored twice (both directions); ``indptr``/``indices`` follow
    scipy.sparse.csr conventions. ``edge_weight`` is per *directed* arc.

    ``Graph`` is the in-RAM backend of the ``GraphStore`` protocol
    (DESIGN.md §15): it shares ``iter_csr_chunks()``/``gather_arcs()`` with
    :class:`repro.core.graphstore.MmapGraphStore` so the partitioning engine
    can consume either, and ``out_of_core`` tells chunk-aware call sites
    which dispatch path applies (the in-RAM paths are byte-identical to
    their pre-protocol behavior).
    """

    out_of_core: ClassVar[bool] = False

    n: int
    indptr: np.ndarray          # (n+1,) int64
    indices: np.ndarray         # (2m,)  int32, neighbor ids
    edge_weight: np.ndarray     # (2m,)  float64
    node_weight: np.ndarray     # (n,)   float64 (used by aggregated graphs)
    # Self-loop weight per node (sum of intra-edge weights folded into the
    # node by aggregation). A self-loop of weight w contributes 2w to the
    # node degree — required for modularity bookkeeping across Leiden levels.
    self_weight: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))

    # ----- constructors ---------------------------------------------------
    @staticmethod
    def from_edges(n: int, src: np.ndarray, dst: np.ndarray,
                   weight: Optional[np.ndarray] = None,
                   node_weight: Optional[np.ndarray] = None,
                   self_weight: Optional[np.ndarray] = None,
                   dedup: bool = True) -> "Graph":
        """Build an undirected graph from a directed edge list.

        Self-loops are dropped; reciprocal arcs are added; duplicates merged
        by summing weights when ``dedup``.
        """
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if weight is None:
            weight = np.ones(src.shape[0], dtype=np.float64)
        weight = np.asarray(weight, dtype=np.float64)
        keep = src != dst
        src, dst, weight = src[keep], dst[keep], weight[keep]
        # symmetrize
        s = np.concatenate([src, dst])
        d = np.concatenate([dst, src])
        w = np.concatenate([weight, weight])
        if dedup and s.size:
            key = s * n + d
            order = np.argsort(key, kind="stable")
            key, s, d, w = key[order], s[order], d[order], w[order]
            uniq, start = np.unique(key, return_index=True)
            w = np.add.reduceat(w, start)
            s = s[start]
            d = d[start]
        counts = np.bincount(s, minlength=n)
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        order = np.argsort(s, kind="stable")
        indices = d[order].astype(np.int32)
        ew = w[order]
        if node_weight is None:
            node_weight = np.ones(n, dtype=np.float64)
        if self_weight is None:
            self_weight = np.zeros(n, dtype=np.float64)
        return Graph(n=n, indptr=indptr, indices=indices, edge_weight=ew,
                     node_weight=np.asarray(node_weight, dtype=np.float64),
                     self_weight=np.asarray(self_weight, dtype=np.float64))

    # ----- basic accessors -------------------------------------------------
    @property
    def num_arcs(self) -> int:
        return int(self.indices.shape[0])

    @property
    def m(self) -> float:
        """Total undirected edge weight (self-loops included)."""
        return float(self.edge_weight.sum() / 2.0 + self.self_weight.sum())

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def neighbor_weights(self, v: int) -> np.ndarray:
        return self.edge_weight[self.indptr[v]:self.indptr[v + 1]]

    def degrees(self) -> np.ndarray:
        """Weighted degree per node (a self-loop of weight w counts 2w)."""
        out = 2.0 * self.self_weight.copy() if self.self_weight.shape[0] \
            else np.zeros(self.n)
        out += np.bincount(self._arc_src(), weights=self.edge_weight,
                           minlength=self.n)
        return out

    def _arc_src(self) -> np.ndarray:
        return np.repeat(np.arange(self.n, dtype=np.int64),
                         np.diff(self.indptr))

    def arcs(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, weight) for every directed arc."""
        return self._arc_src(), self.indices.astype(np.int64), self.edge_weight

    # ----- GraphStore protocol ---------------------------------------------
    def iter_csr_chunks(self) -> Iterator[engine.ArcChunk]:
        """One zero-copy chunk covering the whole CSR (the in-RAM backend's
        trivial implementation of the chunk protocol).

        The span wraps the ``yield``, so it times the *consumer's*
        processing of the chunk — same shape as the mmap backend, where the
        span additionally covers the disk read."""
        src, dst, w = self.arcs()
        ch = engine.ArcChunk(row_start=0, row_stop=self.n, arc_start=0,
                             arc_stop=self.num_arcs, src=src, dst=dst,
                             weight=w)
        obs.counter("graphstore.chunks").inc()
        obs.counter("graphstore.chunk_bytes").inc(
            int(src.nbytes + dst.nbytes + w.nbytes))
        with obs.span("graphstore.chunk", rows=int(self.n),
                      arcs=int(self.num_arcs), backend="ram"):
            yield ch

    def gather_arcs(self, nodes: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(asrc, adst, aw): the CSR slices of all given nodes concatenated,
        in the given node order, without a Python loop."""
        obs.counter("graphstore.gather_calls").inc()
        obs.counter("graphstore.gather_rows").inc(int(nodes.size))
        counts = self.indptr[nodes + 1] - self.indptr[nodes]
        total = int(counts.sum())
        stops = np.cumsum(counts)
        flat = (np.arange(total, dtype=np.int64)
                - np.repeat(stops - counts, counts)
                + np.repeat(self.indptr[nodes], counts))
        asrc = np.repeat(nodes, counts)
        return asrc, self.indices[flat].astype(np.int64), \
            self.edge_weight[flat]

    # ----- structure queries -----------------------------------------------
    def connected_components(self, mask: Optional[np.ndarray] = None) -> np.ndarray:
        """Label connected components (restricted to ``mask`` if given).

        Returns an int array of shape (n,) with component ids; nodes outside
        ``mask`` get -1.
        """
        src, dst, _ = self.arcs()
        return engine.connected_components(self.n, src, dst, mask=mask)

    def num_components(self, mask: Optional[np.ndarray] = None) -> int:
        comp = self.connected_components(mask)
        return int(comp.max() + 1) if (comp >= 0).any() else 0

    def subgraph(self, nodes: np.ndarray) -> Tuple["Graph", np.ndarray]:
        """Induced subgraph; returns (graph, original-node-ids)."""
        nodes = np.asarray(nodes, dtype=np.int64)
        remap = np.full(self.n, -1, dtype=np.int64)
        remap[nodes] = np.arange(nodes.shape[0])
        src, dst, w = self.arcs()
        keep = (remap[src] >= 0) & (remap[dst] >= 0) & (src < dst)
        g = Graph.from_edges(nodes.shape[0], remap[src[keep]],
                             remap[dst[keep]], w[keep],
                             node_weight=self.node_weight[nodes],
                             self_weight=self.self_weight[nodes], dedup=False)
        return g, nodes

    def aggregate(self, labels: np.ndarray) -> "Graph":
        """Quotient graph: one node per label, edge weights summed.

        ``node_weight`` of the quotient = sum of member node weights (so that
        community sizes survive aggregation — required by the Leiden size cap).

        Thin view of :func:`repro.core.engine.quotient_edges`: the deduped
        community arcs become the quotient CSR directly (they come out sorted
        by ``(src, dst)``), intra-community weight becomes the quotient
        node's self-loop, member node weights sum.
        """
        q = engine.quotient_edges(self, labels)
        return Graph(n=q.k, indptr=q.indptr(),
                     indices=q.dst.astype(np.int32), edge_weight=q.weight,
                     node_weight=q.node_weight, self_weight=q.intra)


# --------------------------------------------------------------------------
# Canonical small graph: Zachary's karate club (34 nodes, 78 edges).
# Edge list from Zachary (1977), as distributed with networkx.
# --------------------------------------------------------------------------
_KARATE_EDGES = [
    (0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (0, 6), (0, 7), (0, 8), (0, 10),
    (0, 11), (0, 12), (0, 13), (0, 17), (0, 19), (0, 21), (0, 31), (1, 2),
    (1, 3), (1, 7), (1, 13), (1, 17), (1, 19), (1, 21), (1, 30), (2, 3),
    (2, 7), (2, 8), (2, 9), (2, 13), (2, 27), (2, 28), (2, 32), (3, 7),
    (3, 12), (3, 13), (4, 6), (4, 10), (5, 6), (5, 10), (5, 16), (6, 16),
    (8, 30), (8, 32), (8, 33), (9, 33), (13, 33), (14, 32), (14, 33),
    (15, 32), (15, 33), (18, 32), (18, 33), (19, 33), (20, 32), (20, 33),
    (22, 32), (22, 33), (23, 25), (23, 27), (23, 29), (23, 32), (23, 33),
    (24, 25), (24, 27), (24, 31), (25, 31), (26, 29), (26, 33), (27, 33),
    (28, 31), (28, 33), (29, 32), (29, 33), (30, 32), (30, 33), (31, 32),
    (31, 33), (32, 33),
]


def karate_club() -> Graph:
    e = np.array(_KARATE_EDGES, dtype=np.int64)
    return Graph.from_edges(34, e[:, 0], e[:, 1])


# --------------------------------------------------------------------------
# Synthetic OGB-like datasets (see DESIGN.md §7): SBM with power-law-ish
# block sizes, community-correlated features and labels.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NodeDataset:
    graph: Graph
    features: np.ndarray       # (n, f) float32
    labels: np.ndarray         # (n,) int64  or (n, t) float32 multi-label
    num_classes: int
    train_mask: np.ndarray
    val_mask: np.ndarray
    test_mask: np.ndarray
    multilabel: bool = False
    name: str = "synthetic"


def _sbm_edges(rng: np.random.Generator, block_of: np.ndarray,
               avg_deg_in: float, avg_deg_out: float) -> Tuple[np.ndarray, np.ndarray]:
    """Sample SBM edges via per-node degree targets (fast, O(m))."""
    n = block_of.shape[0]
    num_blocks = int(block_of.max()) + 1
    # intra-block edges: for each block sample deg_in * |B| / 2 pairs
    srcs, dsts = [], []
    for b in range(num_blocks):
        members = np.where(block_of == b)[0]
        nb = members.shape[0]
        if nb < 2:
            continue
        m_in = int(avg_deg_in * nb / 2)
        srcs.append(members[rng.integers(0, nb, m_in)])
        dsts.append(members[rng.integers(0, nb, m_in)])
    # inter-block edges: uniform random pairs
    m_out = int(avg_deg_out * n / 2)
    srcs.append(rng.integers(0, n, m_out))
    dsts.append(rng.integers(0, n, m_out))
    return np.concatenate(srcs), np.concatenate(dsts)


def _ensure_connected(g: Graph, rng: np.random.Generator) -> Graph:
    comp = g.connected_components()
    k = int(comp.max()) + 1
    if k <= 1:
        return g
    # chain a random representative of each extra component to component 0
    reps = [np.where(comp == c)[0] for c in range(k)]
    extra_src = np.array([rng.choice(reps[c]) for c in range(1, k)])
    extra_dst = rng.choice(reps[0], size=k - 1)
    src, dst, w = g.arcs()
    keep = src < dst
    return Graph.from_edges(
        g.n, np.concatenate([src[keep], extra_src]),
        np.concatenate([dst[keep], extra_dst]),
        np.concatenate([w[keep], np.ones(k - 1)]),
        node_weight=g.node_weight, dedup=True)


def make_arxiv_like(n: int = 40_000, num_classes: int = 40,
                    feature_dim: int = 128, avg_deg: float = 13.8,
                    noise: float = 4.0, seed: int = 0,
                    scale: float = 1.0) -> NodeDataset:
    """A citation-network stand-in: sparse SBM, 40 classes (paper's Arxiv:
    169k nodes, 1.17M edges, avg degree ~13.8, 40 classes).

    ``scale`` multiplies the node count (``scale=12.5`` with the default
    ``n`` gives a 500k-node graph); topology generation and partitioning are
    fully vectorized, so 100k+-node graphs are routine (DESIGN.md §10).
    """
    n = max(int(n * scale), 1)
    rng = np.random.default_rng(seed)
    # power-law-ish block sizes over ~4x num_classes latent communities
    num_blocks = num_classes * 4
    sizes = rng.pareto(1.5, num_blocks) + 1.0
    sizes = np.maximum((sizes / sizes.sum() * n).astype(np.int64), 8)
    block_of = np.repeat(np.arange(num_blocks), sizes)[:n]
    if block_of.shape[0] < n:
        block_of = np.concatenate(
            [block_of, rng.integers(0, num_blocks, n - block_of.shape[0])])
    rng.shuffle(block_of)
    src, dst = _sbm_edges(rng, block_of, avg_deg_in=avg_deg * 0.8,
                          avg_deg_out=avg_deg * 0.2)
    g = _ensure_connected(Graph.from_edges(n, src, dst), rng)
    labels = (block_of % num_classes).astype(np.int64)
    # community-correlated gaussian features; ``noise`` is calibrated so that
    # features alone are weakly informative and neighbor aggregation (which
    # averages away the noise) is required — this is what makes partition
    # quality matter for accuracy, as in the real Arxiv benchmark.
    centers = rng.normal(0, 1, (num_blocks, feature_dim))
    feats = (centers[block_of] + rng.normal(0, noise, (n, feature_dim))
             ).astype(np.float32)
    perm = rng.permutation(n)
    tr, va = int(0.6 * n), int(0.8 * n)
    train_mask = np.zeros(n, bool); train_mask[perm[:tr]] = True
    val_mask = np.zeros(n, bool); val_mask[perm[tr:va]] = True
    test_mask = np.zeros(n, bool); test_mask[perm[va:]] = True
    return NodeDataset(g, feats, labels, num_classes, train_mask, val_mask,
                       test_mask, multilabel=False, name="arxiv_like")


def make_proteins_like(n: int = 6_000, num_tasks: int = 112,
                       feature_dim: int = 8, avg_deg: float = 80.0,
                       seed: int = 1, scale: float = 1.0) -> NodeDataset:
    """A dense PPI stand-in: high average degree, multilabel binary tasks
    (paper's Proteins: 132k nodes, 39.5M edges, avg degree 597, 112 tasks).

    ``scale`` multiplies the node count, same contract as
    :func:`make_arxiv_like` (``--dataset proteins --dataset-scale 22`` on
    the pipeline CLI reaches the paper's 132k nodes).
    """
    n = max(int(n * scale), 1)
    rng = np.random.default_rng(seed)
    num_blocks = 24
    block_of = rng.integers(0, num_blocks, n)
    src, dst = _sbm_edges(rng, block_of, avg_deg_in=avg_deg * 0.7,
                          avg_deg_out=avg_deg * 0.3)
    g = _ensure_connected(Graph.from_edges(n, src, dst), rng)
    proto = rng.random((num_blocks, num_tasks)) < 0.3
    flip = rng.random((n, num_tasks)) < 0.15
    labels = (proto[block_of] ^ flip).astype(np.float32)
    feats = rng.normal(0, 1, (n, feature_dim)).astype(np.float32)
    feats[:, 0] = np.log1p(g.degrees()).astype(np.float32)
    perm = rng.permutation(n)
    tr, va = int(0.6 * n), int(0.8 * n)
    train_mask = np.zeros(n, bool); train_mask[perm[:tr]] = True
    val_mask = np.zeros(n, bool); val_mask[perm[tr:va]] = True
    test_mask = np.zeros(n, bool); test_mask[perm[va:]] = True
    return NodeDataset(g, feats, labels, num_tasks, train_mask, val_mask,
                       test_mask, multilabel=True, name="proteins_like")
