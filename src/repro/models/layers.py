"""Shared neural building blocks: norms, RoPE, FFN (+MoE-free variants)."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, dim: Optional[int] = None) -> Dict:
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if "bias" in p:
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


def rms_norm_heads(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6
                   ) -> jnp.ndarray:
    """Per-head RMSNorm on [..., H, Dh] (qwen3 qk_norm)."""
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(cfg: ModelConfig, head_dim: int) -> jnp.ndarray:
    rot = int(head_dim * cfg.rope_fraction)
    rot -= rot % 2
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2,
                                                dtype=jnp.float32) / rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, cfg: ModelConfig
               ) -> jnp.ndarray:
    """x: [..., S, H, Dh]; positions: [..., S]. Rotates the first
    ``rope_fraction`` of the head dim (GLM-style partial rotary)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(cfg, dh)                 # [rot/2]
    rot = freqs.shape[0] * 2
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [...,S,rot/2]
    cos = jnp.cos(angles)[..., :, None, :]            # [..., S, 1, rot/2]
    sin = jnp.sin(angles)[..., :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------
def init_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    s_in = (2.0 / d) ** 0.5
    s_out = (2.0 / f) ** 0.5
    p = {"w_out": (jax.random.normal(ks[2], (f, d)) * s_out).astype(dt)}
    if cfg.ffn_activation == "swiglu":
        p["w_gate"] = (jax.random.normal(ks[0], (d, f)) * s_in).astype(dt)
        p["w_up"] = (jax.random.normal(ks[1], (d, f)) * s_in).astype(dt)
    else:
        p["w_up"] = (jax.random.normal(ks[1], (d, f)) * s_in).astype(dt)
    if cfg.ffn_bias:
        p["b_up"] = jnp.zeros((f,), dt)
        p["b_out"] = jnp.zeros((d,), dt)
    return p


def ffn_forward(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.ffn_activation == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = x @ p["w_up"]
        if "b_up" in p:
            h = h + p["b_up"]
        if cfg.ffn_activation == "squared_relu":      # nemotron-4
            r = jax.nn.relu(h)
            h = r * r
        else:
            h = jax.nn.gelu(h)
    out = h @ p["w_out"]
    if "b_out" in p:
        out = out + p["b_out"]
    return out
