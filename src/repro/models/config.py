"""Model configuration for the assigned architecture zoo.

One frozen dataclass covers all six architecture families; family-specific
fields default to "off". Every config in :mod:`repro.configs` cites its
source model card / paper.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads

    # --- attention flavor ---------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False             # per-head RMSNorm on q,k (qwen3)
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0        # glm4 rotates half the head dim
    attention: str = "full"           # full | sliding
    window: int = 8192                # sliding-window size
    causal: bool = True

    # --- FFN -----------------------------------------------------------------
    ffn_activation: str = "swiglu"    # swiglu | squared_relu | gelu
    ffn_bias: bool = False

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden dim
    first_k_dense: int = 0            # leading dense layers (deepseek-v2)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # Pad the expert WEIGHT stacks to this count (0 = no padding). Dummy
    # experts get -inf router logits and are never routed; padding restores
    # mesh-divisibility so the E axis actually shards (qwen2-moe's 60
    # experts don't divide the 16-way model axis -> silently replicated
    # otherwise; §Perf iteration P3.1).
    experts_pad_to: int = 0

    # --- MLA (deepseek-v2) ---------------------------------------------------
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- SSM / hybrid ---------------------------------------------------------
    # block pattern, repeated/truncated to num_layers:
    #   "attn" | "mlstm" | "slstm" | "mamba" | "shared_attn"
    block_pattern: Tuple[str, ...] = ()
    ssm_state_dim: int = 0
    conv_kernel: int = 4
    chunk_size: int = 128             # chunked linear-attention chunk

    # --- encoder-decoder ------------------------------------------------------
    encoder_layers: int = 0           # >0 -> enc-dec (seamless)
    enc_seq_divisor: int = 8          # encoder frames = seq/divisor

    # --- modality frontend stub -----------------------------------------------
    frontend: str = "none"            # none | audio | vision
    num_patch_tokens: int = 0         # vision tokens prepended (phi-3-v)

    # --- numerics / structure ---------------------------------------------------
    dtype: str = "bfloat16"
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    scan_layers: bool = True          # lax.scan over stacked layer params
    remat: bool = True                # activation checkpointing per layer
    # "full"  — recompute everything in backward (min memory, +1/3 flops)
    # "dots"  — save matmul outputs, recompute only elementwise chains
    #           (§Perf P2.2: trades HBM capacity for bandwidth+flops)
    remat_policy: str = "full"
    # route single-token decode attention through the Pallas flash-decode
    # kernel (repro/kernels/flash_decode.py): interpret=True on CPU,
    # compiled on TPU. jnp path remains the default for dry-run lowering
    # (the interpreter would inline into the SPMD HLO).
    use_flash_decode: bool = False
    # unroll inner chunk loops (attention/loss/linear-attention) instead of
    # lax.scan/map: XLA's HloCostAnalysis counts while bodies ONCE, so the
    # roofline dry-run lowers with unroll=True + scan_layers=False to get
    # trip-count-correct FLOP/byte numbers (see launch/dryrun.py).
    unroll: bool = False
    tie_embeddings: bool = False

    # long-context strategy for the long_500k shape:
    #   "native"  — SSM/linear blocks handle it as-is
    #   "sliding" — dense archs switch to sliding-window KV cache
    long_context: str = "sliding"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.num_heads, 1))

    @property
    def group_size(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def blocks(self) -> Tuple[str, ...]:
        """Per-layer block kinds, length num_layers."""
        if not self.block_pattern:
            return ("attn",) * self.num_layers
        reps = (self.num_layers + len(self.block_pattern) - 1) \
            // len(self.block_pattern)
        return (self.block_pattern * reps)[: self.num_layers]

    def is_moe_layer(self, idx: int) -> bool:
        return self.num_experts > 0 and idx >= self.first_k_dense

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d = self.d_model
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i, kind in enumerate(self.blocks):
            if kind in ("attn", "shared_attn"):
                if self.mla:
                    qd = self.q_lora_rank or d
                    n += d * qd + qd * self.num_heads * (
                        self.qk_nope_head_dim + self.qk_rope_head_dim)
                    n += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    n += self.kv_lora_rank * self.num_heads * (
                        self.qk_nope_head_dim + self.v_head_dim)
                    n += self.num_heads * self.v_head_dim * d
                else:
                    n += d * self.head_dim * (self.num_heads
                                              + 2 * self.num_kv_heads)
                    n += self.num_heads * self.head_dim * d
                    if self.encoder_layers:   # decoder cross-attention
                        n += d * self.head_dim * (self.num_heads
                                                  + 2 * self.num_kv_heads)
                        n += self.num_heads * self.head_dim * d
            elif kind == "mlstm":
                # wq,wk,wv,wo_gate,w_out (5 d^2) + gates
                n += 5 * d * d + 2 * d * self.num_heads
            elif kind == "slstm":
                # w_in (4d^2) + block-diag recurrent (4 d dh) + w_out
                dh = d // max(self.num_heads, 1)
                n += 4 * d * d + 4 * d * dh + d * d
            elif kind == "mamba":
                dinner = 2 * d
                n += d * dinner * 2 + dinner * self.ssm_state_dim * 2 \
                    + dinner * d
            # FFN
            if kind in ("attn",) or (kind in ("mlstm",) and self.d_ff):
                if self.is_moe_layer(i):
                    mult = 3 if self.ffn_activation == "swiglu" else 2
                    n += (self.num_experts + self.num_shared_experts) \
                        * mult * d * self.moe_d_ff
                    n += d * self.num_experts   # router
                elif self.d_ff:
                    mult = 3 if self.ffn_activation == "swiglu" else 2
                    n += mult * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        mult = 3 if self.ffn_activation == "swiglu" else 2
        moe_layers = sum(1 for i in range(self.num_layers)
                         if self.is_moe_layer(i))
        all_e = (self.num_experts + self.num_shared_experts) * mult \
            * self.d_model * self.moe_d_ff * moe_layers
        act_e = (self.top_k + self.num_shared_experts) * mult \
            * self.d_model * self.moe_d_ff * moe_layers
        return full - all_e + act_e

    def reduced(self, num_layers: int = 2, d_model: int = 256,
                num_experts: int = 4) -> "ModelConfig":
        """The smoke-test variant: same family, tiny dims (brief: <=2 layers,
        d_model<=512, <=4 experts)."""
        scale = d_model / self.d_model
        heads = max(2, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, heads))
        changes = dict(
            num_layers=num_layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=max(16, d_model // heads),
            d_ff=max(32, int(self.d_ff * scale)) if self.d_ff else 0,
            vocab_size=512,
            scan_layers=self.scan_layers,
            remat=False,
            dtype="float32",
            encoder_layers=min(self.encoder_layers, 2),
            num_patch_tokens=min(self.num_patch_tokens, 8),
            window=64,
        )
        if self.num_experts:
            changes.update(
                num_experts=min(num_experts, self.num_experts),
                num_shared_experts=min(1, self.num_shared_experts),
                top_k=min(2, self.top_k),
                moe_d_ff=max(32, int(self.moe_d_ff * scale)),
                first_k_dense=min(self.first_k_dense, 1),
            )
        if self.mla:
            changes.update(q_lora_rank=64, kv_lora_rank=32,
                           qk_nope_head_dim=32, qk_rope_head_dim=16,
                           v_head_dim=32)
        if self.ssm_state_dim:
            changes.update(ssm_state_dim=min(16, self.ssm_state_dim))
        if self.block_pattern:
            changes.update(block_pattern=self.block_pattern)
        return dataclasses.replace(self, **changes)
