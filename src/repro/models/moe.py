"""Mixture-of-Experts with sort-based token dispatch (expert-parallel).

Dispatch is Megablocks-style but static-shape: tokens are argsorted by their
routed expert, positioned within per-expert capacity buckets, and scattered
into an [E, C, d] buffer. Expert FFNs run vmapped over E; the buffer's E axis
is sharded over the ``model`` mesh axis, so the scatter/gather lowers to the
all-to-all traffic that expert parallelism actually costs — which is what the
LF expert-placement optimization (repro.core.expert_placement) minimizes.

Shared experts (qwen2-moe: 4, deepseek-v2: 2) run densely on every token.
The router adds the standard load-balance auxiliary loss (Switch eq. 4).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import ffn_forward, init_ffn


def _padded_e(cfg: ModelConfig) -> int:
    return max(cfg.experts_pad_to, cfg.num_experts)


def init_moe(key, cfg: ModelConfig) -> Dict:
    d, e, f = cfg.d_model, _padded_e(cfg), cfg.moe_d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    s_in, s_out = (2.0 / d) ** 0.5, (2.0 / f) ** 0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * s_in).astype(dt),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * s_in).astype(dt),
        "w_out": (jax.random.normal(ks[3], (e, f, d)) * s_out).astype(dt),
    }
    if cfg.num_shared_experts:
        sk = jax.random.split(jax.random.fold_in(key, 7),
                              cfg.num_shared_experts)
        p["shared"] = [init_ffn(sk[i], cfg, d_ff=cfg.moe_d_ff)
                       for i in range(cfg.num_shared_experts)]
    return p


def _expert_ffn(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: [E, C, d] -> [E, C, d], vmapped over experts."""
    def one(wg, wu, wo, xe):
        if cfg.ffn_activation == "swiglu":
            h = jax.nn.silu(xe @ wg) * (xe @ wu)
        else:
            h = jax.nn.gelu(xe @ wu)
        return h @ wo
    return jax.vmap(one)(p["w_gate"], p["w_up"], p["w_out"], x)


def moe_forward(p: Dict, cfg: ModelConfig, x: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar).

    Dispatch is GROUP-LOCAL (one group per batch row, vmapped over B): the
    argsort/bucketing arithmetic then never crosses the data-sharded batch
    axis, so SPMD keeps it entirely on-device; the only communication left
    is the genuine token<->expert resharding at the [B, E, C, d] buffer
    boundary (data axis <-> model axis). A global-T dispatch instead makes
    XLA partition a distributed sort and all-reduce full dispatch buffers —
    measured 4x worse collective traffic (EXPERIMENTS.md §Perf P3.2).
    Capacity is per (row, expert): C = ceil(cf * k * S / E_real)."""
    b, s, d = x.shape
    e, k = _padded_e(cfg), cfg.top_k
    logits = (x.astype(jnp.float32) @ p["router"])           # [B, S, E_pad]
    if e > cfg.num_experts:      # mask dummy padding experts (never routed)
        pad_mask = jnp.arange(e) >= cfg.num_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # [B, S, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)              # renormalize

    # ---- load-balance aux loss (Switch): E * sum_e f_e * P_e --------------
    me = probs.reshape(-1, e).mean(axis=0)
    onehot_top1 = jax.nn.one_hot(expert_idx[..., 0].reshape(-1), e)
    ce = onehot_top1.mean(axis=0)                            # token fraction
    aux = cfg.num_experts * jnp.sum(me * ce)     # real experts only

    cap = int(cfg.capacity_factor * k * s / cfg.num_experts) + 1

    # ---- one-hot einsum dispatch (Switch-style): NO sorts, NO data-
    # dependent gathers — every op is a dense matmul/cumsum that the SPMD
    # partitioner tiles exactly (dispatch einsum local per (data, model)
    # tile; only the combine contraction all-reduces a [B,S,d] partial).
    # Position of each token within its expert's capacity bucket, assigned
    # in routing-priority order (k=0 strongest), per batch row:
    dispatch = jnp.zeros((b, s, e, cap), x.dtype)            # [B,S,E,C]
    combine_w = jnp.zeros((b, s, e, cap), jnp.float32)
    offset = jnp.zeros((b, 1, e), jnp.float32)
    for kk in range(k):
        m = jax.nn.one_hot(expert_idx[..., kk], e)           # [B, S, E]
        pos = jnp.cumsum(m, axis=1) - m + offset             # pos before token
        valid = m * (pos < cap)
        slot_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap) # [B,S,E,C]
        dispatch = dispatch + (slot_oh * valid[..., None]).astype(x.dtype)
        combine_w = combine_w + slot_oh * (
            valid * gate_vals[..., kk:kk + 1])[..., None]
        offset = offset + m.sum(axis=1, keepdims=True)
    buf = jnp.einsum("bsec,bsd->becd", dispatch, x)          # [B, E, C, d]
    # ---- expert compute (E axis shards over `model`, B over data) ----------
    if cfg.ffn_activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) \
            * jnp.einsum("becd,edf->becf", buf, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", buf, p["w_up"]))
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_out"])    # [B, E, C, d]
    # ---- combine -------------------------------------------------------------
    out = jnp.einsum("bsec,becd->bsd", combine_w.astype(x.dtype), out_buf)
    # ---- shared experts run densely ----------------------------------------
    if cfg.num_shared_experts:
        for sp in p["shared"]:
            out = out + ffn_forward(sp, cfg, x)
    return out, aux.astype(jnp.float32)


def moe_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Single-token MoE ([B, 1, d]): dense top-k gather, no capacity drop."""
    b, _, d = x.shape
    logits = x[:, 0].astype(jnp.float32) @ p["router"]
    e_pad = p["router"].shape[-1]
    if e_pad > cfg.num_experts:
        logits = jnp.where(jnp.arange(e_pad)[None, :] >= cfg.num_experts,
                           -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)              # [B, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    wg = p["w_gate"][idx]                                    # [B, K, d, f]
    wu = p["w_up"][idx]
    wo = p["w_out"][idx]
    xe = x[:, 0][:, None, None, :]                           # [B, 1, 1, d]
    if cfg.ffn_activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("bd,bkdf->bkf", x[:, 0], wg)) * \
            jnp.einsum("bd,bkdf->bkf", x[:, 0], wu)
    else:
        h = jax.nn.gelu(jnp.einsum("bd,bkdf->bkf", x[:, 0], wu))
    y = jnp.einsum("bkf,bkfd->bkd", h, wo)
    out = (y * gate[..., None].astype(x.dtype)).sum(axis=1)[:, None]
    if cfg.num_shared_experts:
        for sp in p["shared"]:
            out = out + ffn_forward(sp, cfg, x)
    return out
