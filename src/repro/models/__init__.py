"""Model zoo: the 10 assigned architectures over 6 families."""
from .config import ModelConfig
from .lm import init_model, init_cache, model_hidden_train, train_loss, serve_step
from .inputs import SHAPES, InputShape, effective_config, input_specs, make_batch

__all__ = ["ModelConfig", "init_model", "init_cache", "model_hidden_train",
           "train_loss", "serve_step", "SHAPES", "InputShape",
           "effective_config", "input_specs", "make_batch"]
