"""Attention: GQA (+qk_norm, qkv bias, partial RoPE, sliding window), MLA,
cross-attention; train (chunked-causal) and decode (KV cache) paths.

Training attention is **query-chunked**: a lax.scan over query blocks keeps
the logits buffer at [B, H, Cq, S] instead of [B, H, S, S] — the flash-
attention memory profile expressed in XLA-native ops (the Pallas decode
kernel in repro/kernels handles the serving side; see DESIGN.md §3).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, rms_norm_heads

Q_CHUNK = 512


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig) -> Dict:
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    s = (1.0 / d) ** 0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, h * dh)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, hkv * dh)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, hkv * dh)) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (h * dh, d)) * s).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dt)
        p["bk"] = jnp.zeros((hkv * dh,), dt)
        p["bv"] = jnp.zeros((hkv * dh,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def _project_qkv(p: Dict, cfg: ModelConfig, x: jnp.ndarray, positions
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    b, s, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rms_norm_heads(q, p["q_norm"])
        k = rms_norm_heads(k, p["k_norm"])
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    return q, k, v


# ---------------------------------------------------------------------------
# core attention math (query-chunked)
# ---------------------------------------------------------------------------
def _attend_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool, window: Optional[int],
                    q_offset: int = 0, unroll: bool = False) -> jnp.ndarray:
    """q: [B, Sq, H, Dh]; k, v: [B, Sk, Hkv, Dh] -> [B, Sq, H, Dh].

    Scans over query chunks; each chunk computes masked softmax against the
    full K. ``q_offset`` positions queries within the kv timeline."""
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // hkv
    scale = dh ** -0.5
    cq = min(Q_CHUNK, sq)
    nc = (sq + cq - 1) // cq
    pad = nc * cq - sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    qc = qp.reshape(b, nc, cq, h, dh).transpose(1, 0, 2, 3, 4)  # [nc,B,Cq,H,Dh]
    kg = k.reshape(b, sk, hkv, 1, dh)
    vg = v.reshape(b, sk, hkv, 1, dv)
    kpos = jnp.arange(sk)

    def one_chunk(ci, qi):
        # qi: [B, Cq, H, Dh] -> group view [B, Cq, Hkv, G, Dh]
        qg = qi.reshape(b, cq, hkv, g, dh)
        logits = jnp.einsum("bqhgd,bkhud->bhgqk", qg.astype(jnp.float32),
                            kg.astype(jnp.float32)) * scale  # [B,Hkv,G,Cq,Sk]
        qpos = q_offset + ci * cq + jnp.arange(cq)
        mask = jnp.ones((cq, sk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bkhud->bqhgd", probs,
                         vg.astype(jnp.float32))
        return out.reshape(b, cq, h, dv)

    if unroll:
        outs = jnp.stack([one_chunk(ci, qc[ci]) for ci in range(nc)])
    else:
        outs = jax.lax.map(lambda args: one_chunk(*args),
                           (jnp.arange(nc), qc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nc * cq, h, dv)
    return out[:, :sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# public paths
# ---------------------------------------------------------------------------
def attention_train(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                    positions: jnp.ndarray, causal: Optional[bool] = None,
                    return_kv: bool = False):
    """Full-sequence self-attention. x: [B, S, d]."""
    b, s, d = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    window = cfg.window if cfg.attention == "sliding" else None
    causal = cfg.causal if causal is None else causal
    out = _attend_chunked(q, k, v, causal, window, unroll=cfg.unroll)
    out = out.reshape(b, s, -1) @ p["wo"]
    if return_kv:
        return out, k, v
    return out


def attention_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                     cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                     length: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x: [B, 1, d]; cache_*: [B, S, Hkv, Dh]; length: [B].

    Returns (out [B, 1, d], new_cache_k, new_cache_v). With a sliding-window
    config the cache is a ring buffer of size ``window``."""
    b, _, d = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s_cache = cache_k.shape[1]
    pos = length[:, None]                                    # [B, 1]
    q, k, v = _project_qkv(p, cfg, x, pos)
    slot = length % s_cache if cfg.attention == "sliding" else length
    idx = slot[:, None, None, None]
    onehot = (jnp.arange(s_cache)[None, :, None, None] == idx)
    cache_k = jnp.where(onehot, k.astype(cache_k.dtype), cache_k)
    cache_v = jnp.where(onehot, v.astype(cache_v.dtype), cache_v)
    # attend: valid = entries < length+1 (ring buffer: all filled slots —
    # always a PREFIX of the cache, so a prefix-length mask covers both)
    if cfg.attention == "sliding":
        filled = jnp.minimum(length + 1, s_cache)
    else:
        filled = length + 1
    if cfg.use_flash_decode:
        from repro.kernels.ops import flash_decode
        out = jax.vmap(flash_decode)(q[:, 0], cache_k, cache_v, filled)
        out = out.reshape(b, 1, h * dh).astype(x.dtype)
        return out @ p["wo"], cache_k, cache_v
    kpos = jnp.arange(s_cache)[None, :]
    valid = kpos < filled[:, None]
    g = h // hkv
    qg = q.reshape(b, hkv, g, dh)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        cache_k.astype(jnp.float32)) * dh ** -0.5
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, cache_v.astype(jnp.float32))
    out = out.reshape(b, 1, h * dh).astype(x.dtype)
    return out @ p["wo"], cache_k, cache_v


def cross_attention_train(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                          memory: jnp.ndarray) -> jnp.ndarray:
    """Decoder->encoder cross attention (no RoPE on memory side)."""
    b, s, d = x.shape
    sm = memory.shape[1]
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (memory @ p["wk"]).reshape(b, sm, hkv, dh)
    v = (memory @ p["wv"]).reshape(b, sm, hkv, dh)
    out = _attend_chunked(q, k, v, causal=False, window=None,
                          unroll=cfg.unroll)
    return out.reshape(b, s, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (deepseek-v2)
# ---------------------------------------------------------------------------
def init_mla(key, cfg: ModelConfig) -> Dict:
    d, h = cfg.d_model, cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    s = (1.0 / d) ** 0.5
    return {
        # query path: x -> q_lora -> per-head (nope + rope)
        "w_dq": (jax.random.normal(ks[0], (d, qr)) * s).astype(dt),
        "w_uq": (jax.random.normal(ks[1], (qr, h * (dn + dr)))
                 * (1.0 / qr) ** 0.5).astype(dt),
        # kv path: x -> c_kv (compressed) + shared k_rope
        "w_dkv": (jax.random.normal(ks[2], (d, kvr + dr)) * s).astype(dt),
        "w_uk": (jax.random.normal(ks[3], (kvr, h * dn))
                 * (1.0 / kvr) ** 0.5).astype(dt),
        "w_uv": (jax.random.normal(ks[4], (kvr, h * dv))
                 * (1.0 / kvr) ** 0.5).astype(dt),
        "wo": (jax.random.normal(ks[5], (h * dv, d))
               * (1.0 / (h * dv)) ** 0.5).astype(dt),
    }


def _mla_qkv(p: Dict, cfg: ModelConfig, x: jnp.ndarray, positions):
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q = (x @ p["w_dq"]) @ p["w_uq"]
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg)
    ckv_full = x @ p["w_dkv"]                       # [B, S, kvr + dr]
    c_kv, k_rope = ckv_full[..., :cfg.kv_lora_rank], ckv_full[..., cfg.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_train(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
              positions: jnp.ndarray, return_kv: bool = False):
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, dn)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, dv)
    # pack rope dims alongside nope dims; shared k_rope broadcast per head
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          (b, s, h, dr))], axis=-1)
    out = _attend_chunked(q, k, v, causal=True, window=None,
                          unroll=cfg.unroll)
    out = out.reshape(b, s, h * dv) @ p["wo"]
    if return_kv:
        return out, c_kv, k_rope     # compressed cache (the MLA win)
    return out


def mla_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
               cache_ckv: jnp.ndarray, cache_krope: jnp.ndarray,
               length: jnp.ndarray):
    """Absorbed MLA decode: attends in the compressed kv_lora space, so the
    cache is [B, S, kvr] + [B, S, dr] — the paper's (DeepSeek's) memory win.

    out = softmax( q_nope·W_uk^T ckv + q_rope·k_rope ) (ckv W_uv) W_o
    """
    b = x.shape[0]
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    s_cache = cache_ckv.shape[1]
    pos = length[:, None]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, pos)
    # write cache at `length`
    onehot = (jnp.arange(s_cache)[None, :] == length[:, None])[..., None]
    cache_ckv = jnp.where(onehot, c_kv.astype(cache_ckv.dtype), cache_ckv)
    cache_krope = jnp.where(onehot, k_rope.astype(cache_krope.dtype),
                            cache_krope)
    # absorb W_uk into the query:  q_abs [B, H, kvr]
    w_uk = p["w_uk"].reshape(kvr, h, dn)            # [kvr, H, Dn]
    q_abs = jnp.einsum("bhd,khd->bhk", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    logits = jnp.einsum("bhk,bsk->bhs", q_abs,
                        cache_ckv.astype(jnp.float32))
    logits += jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                         cache_krope.astype(jnp.float32))
    logits *= (dn + dr) ** -0.5
    valid = jnp.arange(s_cache)[None, :] <= length[:, None]
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhs,bsk->bhk", probs, cache_ckv.astype(jnp.float32))
    w_uv = p["w_uv"].reshape(kvr, h, dv)
    out = jnp.einsum("bhk,khd->bhd", ctx, w_uv.astype(jnp.float32))
    out = out.reshape(b, 1, h * dv).astype(x.dtype)
    return out @ p["wo"], cache_ckv, cache_krope
