"""Input shapes and ShapeDtypeStruct specs for every (arch × shape) pair.

The four assigned input shapes:

    train_4k       seq_len=4,096    global_batch=256   (training)
    prefill_32k    seq_len=32,768   global_batch=32    (inference-prefill)
    decode_32k     seq_len=32,768   global_batch=128   (inference-decode)
    long_500k      seq_len=524,288  global_batch=1     (long-context-decode)

Decode shapes lower ``serve_step`` (ONE token, cache of seq_len); train_4k
lowers ``train_step``; prefill_32k lowers ``prefill_step``. ``long_500k``
switches pure-attention configs to the sliding-window variant
(cfg.long_context == "sliding"); SSM/hybrid archs run natively.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .lm import init_cache

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def effective_config(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Apply the shape-conditional variants (sliding window for long_500k
    on archs that carry full-attention blocks)."""
    if shape_name == "long_500k" and "attn" in \
            [b for b in cfg.blocks] + (["attn"] if "shared_attn" in
                                       cfg.blocks else []):
        if cfg.long_context == "sliding" or "shared_attn" in cfg.blocks:
            return dataclasses.replace(cfg, attention="sliding")
    return cfg


def enc_len_for(cfg: ModelConfig, shape: InputShape) -> int:
    """Encoder memory length for enc-dec archs (stub audio frontend)."""
    return max(cfg.d_model // 8, min(shape.seq_len // cfg.enc_seq_divisor,
                                     4096))


def input_specs(cfg: ModelConfig, shape_name: str,
                shape: InputShape | None = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this shape
    (weak-type-correct, no device allocation). ``shape`` overrides the
    registry entry (used by the dry-run's sequence-extrapolation)."""
    shape = shape or SHAPES[shape_name]
    cfg = effective_config(cfg, shape_name)
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        batch: Dict[str, Any] = {
            "tokens": SDS((b, s), jnp.int32),
            "loss_mask": SDS((b, s), jnp.float32),
        }
        if cfg.frontend == "vision":
            batch["patch_embeds"] = SDS((b, cfg.num_patch_tokens,
                                         cfg.d_model), dt)
        if cfg.frontend == "audio":
            batch["frames"] = SDS((b, enc_len_for(cfg, shape), cfg.d_model),
                                  dt)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {
            "tokens": SDS((b, s), jnp.int32),
        }
        if cfg.frontend == "vision":
            batch["patch_embeds"] = SDS((b, cfg.num_patch_tokens,
                                         cfg.d_model), dt)
        if cfg.frontend == "audio":
            batch["frames"] = SDS((b, enc_len_for(cfg, shape), cfg.d_model),
                                  dt)
        return {"batch": batch}
    # decode: tokens + cache + lengths
    enc = enc_len_for(cfg, shape) if cfg.encoder_layers else 0
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s, enc_len=enc))
    return {
        "tokens": SDS((b, 1), jnp.int32),
        "cache": cache,
        "lengths": SDS((b,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Synthetic concrete batches (smoke tests / examples / benchmarks)
# ---------------------------------------------------------------------------
def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0
               ) -> Dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    dt = jnp.dtype(cfg.dtype)
    out: Dict[str, jnp.ndarray] = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                              jnp.int32),
        "loss_mask": jnp.ones((batch, seq), jnp.float32),
    }
    if cfg.frontend == "vision":
        p = cfg.num_patch_tokens
        out["patch_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (batch, p, cfg.d_model)), dt)
        out["loss_mask"] = out["loss_mask"].at[:, :p].set(0.0)
    if cfg.frontend == "audio":
        e = max(8, seq // cfg.enc_seq_divisor)
        out["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (batch, e, cfg.d_model)), dt)
    return out
