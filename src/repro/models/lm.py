"""Full model assembly: decoder-only (dense / MoE / SSM / hybrid / VLM) and
encoder-decoder (audio) language models.

Structure:
  params = {
    "embed":   [V, d] token embedding (bf16)
    "layers":  scan-stacked layer pytree (homogeneous archs) OR list
    "first_dense": list of dense layers before MoE stack (deepseek-v2)
    "shared_attn": one shared attention+FFN block (zamba2)
    "encoder": {"layers": ..., "final_norm": ...}        (seamless)
    "final_norm", "head" ([d, V], absent when tied)
  }

Homogeneous decoders use lax.scan over stacked layer params (small HLO for
96-layer models); heterogeneous patterns (xLSTM, zamba2) use a python loop.
Each layer is wrapped in jax.checkpoint when cfg.remat.

The LM loss is sequence-chunked (scan over S blocks): the [B, Sc, V] logits
buffer never materializes for the full sequence — essential for the 151k/256k
vocabularies at seq 4096 on a 16 GB chip.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (attention_decode, attention_train,
                        cross_attention_train, init_attention, init_mla,
                        mla_decode, mla_train)
from .config import ModelConfig
from .layers import apply_norm, ffn_forward, init_ffn, init_norm
from .moe import init_moe, moe_decode, moe_forward
from .ssm import (init_mamba, init_mamba_state, init_mlstm, init_mlstm_state,
                  init_slstm, init_slstm_state, mamba_decode_step,
                  mamba_forward, mlstm_decode_step, mlstm_forward,
                  slstm_decode_step, slstm_forward)

PyTree = Any
LOSS_CHUNK = 512


def _remat(f, cfg: ModelConfig):
    """Per-layer activation checkpointing with the configured policy."""
    if not cfg.remat:
        return f
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(f)




# ---------------------------------------------------------------------------
# layer init / apply
# ---------------------------------------------------------------------------
def _init_layer(key, cfg: ModelConfig, kind: str, moe: bool) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict = {}
    if kind == "attn":
        p["ln1"] = init_norm(cfg)
        p["attn"] = init_mla(ks[0], cfg) if cfg.mla else init_attention(ks[0], cfg)
        if cfg.encoder_layers:          # decoder of an enc-dec model
            p["ln_cross"] = init_norm(cfg)
            p["cross"] = init_attention(ks[2], cfg)
        p["ln2"] = init_norm(cfg)
        p["ffn"] = init_moe(ks[1], cfg) if moe else init_ffn(ks[1], cfg)
    elif kind == "enc_attn":
        p["ln1"] = init_norm(cfg)
        p["attn"] = init_attention(ks[0], cfg)
        p["ln2"] = init_norm(cfg)
        p["ffn"] = init_ffn(ks[1], cfg)
    elif kind == "mlstm":
        p["ln1"] = init_norm(cfg)
        p["mlstm"] = init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["ln1"] = init_norm(cfg)
        p["slstm"] = init_slstm(ks[0], cfg)
    elif kind == "mamba":
        p["ln1"] = init_norm(cfg)
        p["mamba"] = init_mamba(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def _apply_layer_train(p: Dict, cfg: ModelConfig, kind: str, moe: bool,
                       x: jnp.ndarray, positions: jnp.ndarray,
                       memory: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "enc_attn"):
        h = apply_norm(p["ln1"], x)
        if cfg.mla and kind == "attn":
            x = x + mla_train(p["attn"], cfg, h, positions)
        else:
            x = x + attention_train(p["attn"], cfg, h, positions,
                                    causal=(kind == "attn"))
        if "cross" in p and memory is not None:
            h = apply_norm(p["ln_cross"], x)
            x = x + cross_attention_train(p["cross"], cfg, h, memory)
        h = apply_norm(p["ln2"], x)
        if moe:
            out, aux = moe_forward(p["ffn"], cfg, h)
            x = x + out
        else:
            x = x + ffn_forward(p["ffn"], cfg, h)
    elif kind == "mlstm":
        h = apply_norm(p["ln1"], x)
        out, _ = mlstm_forward(p["mlstm"], cfg, h)
        x = x + out
    elif kind == "slstm":
        h = apply_norm(p["ln1"], x)
        out, _ = slstm_forward(p["slstm"], cfg, h)
        x = x + out
    elif kind == "mamba":
        h = apply_norm(p["ln1"], x)
        out, _ = mamba_forward(p["mamba"], cfg, h)
        x = x + out
    return x, aux


def _apply_layer_prefill(p: Dict, cfg: ModelConfig, kind: str, moe: bool,
                         x: jnp.ndarray, positions: jnp.ndarray,
                         memory: Optional[jnp.ndarray]):
    """Like _apply_layer_train but also returns this layer's cache entry."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        h = apply_norm(p["ln1"], x)
        if cfg.mla:
            out, ckv, krope = mla_train(p["attn"], cfg, h, positions,
                                        return_kv=True)
            cache = {"ckv": ckv, "krope": krope}
        else:
            out, k, v = attention_train(p["attn"], cfg, h, positions,
                                        return_kv=True)
            cache = {"k": k, "v": v}
        x = x + out
        if "cross" in p and memory is not None:
            h = apply_norm(p["ln_cross"], x)
            x = x + cross_attention_train(p["cross"], cfg, h, memory)
        h = apply_norm(p["ln2"], x)
        if moe:
            out, aux = moe_forward(p["ffn"], cfg, h)
            x = x + out
        else:
            x = x + ffn_forward(p["ffn"], cfg, h)
    elif kind == "mlstm":
        h = apply_norm(p["ln1"], x)
        out, cache = mlstm_forward(p["mlstm"], cfg, h)
        x = x + out
    elif kind == "slstm":
        h = apply_norm(p["ln1"], x)
        out, cache = slstm_forward(p["slstm"], cfg, h)
        x = x + out
    elif kind == "mamba":
        h = apply_norm(p["ln1"], x)
        out, cache = mamba_forward(p["mamba"], cfg, h, return_state=True)
        x = x + out
    else:
        raise ValueError(kind)
    return x, aux, cache


def prefill_step(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]
                 ) -> Tuple[jnp.ndarray, PyTree, jnp.ndarray]:
    """Process a full prompt; returns (last-token logits [B, V], cache,
    lengths [B]). The cache is sized exactly to the prompt — the serving
    layer concatenates growth room before decode if needed."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    if (pe := batch.get("patch_embeds")) is not None:
        x = jnp.concatenate([pe.astype(x.dtype), x[:, pe.shape[1]:]], axis=1)
    memory = (_run_encoder(params, cfg, batch["frames"])
              if batch.get("frames") is not None else None)
    positions = jnp.arange(s)[None, :]

    blocks = cfg.blocks
    homogeneous = all(bk == "attn" for bk in blocks) and not cfg.block_pattern
    cache: Dict = {}
    if homogeneous and cfg.scan_layers:
        fd_caches = []
        for lp in params.get("first_dense", []):
            x, _, c = _apply_layer_prefill(lp, cfg, "attn", False, x,
                                           positions, memory)
            fd_caches.append(c)

        def body(x, lp):
            def f(x):
                return _apply_layer_prefill(lp, cfg, "attn",
                                            cfg.num_experts > 0, x,
                                            positions, memory)
            f = _remat(f, cfg)
            x, _, c = f(x)
            return x, c

        x, layer_caches = jax.lax.scan(body, x, params["layers"])
        cache["first_dense"] = fd_caches
        cache["layers"] = layer_caches
    else:
        per_layer = []
        for i, kind in enumerate(blocks):
            lp = (params["shared_attn"] if kind == "shared_attn"
                  else params["layers"][i])
            k = "attn" if kind == "shared_attn" else kind
            x, _, c = _apply_layer_prefill(lp, cfg, k, cfg.is_moe_layer(i),
                                           x, positions, memory)
            per_layer.append(c)
        cache["layers"] = per_layer
    if memory is not None:
        cache["memory"] = memory
    h = apply_norm(params["final_norm"], x)
    logits = (h[:, -1] @ _head_weight(params)).astype(jnp.float32)
    lengths = jnp.full((b,), s, jnp.int32)
    return logits, cache, lengths


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------
def init_model(key, cfg: ModelConfig) -> PyTree:
    dt = jnp.dtype(cfg.dtype)
    k_embed, k_layers, k_head, k_enc, k_shared, k_dense = \
        jax.random.split(key, 6)
    params: Dict = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model))
                  * 0.02).astype(dt),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab_size))
            * (1.0 / cfg.d_model) ** 0.5).astype(dt)

    blocks = cfg.blocks
    homogeneous = all(b == "attn" for b in blocks) and not cfg.block_pattern
    if homogeneous and cfg.scan_layers:
        n_moe_start = cfg.first_k_dense if cfg.num_experts else 0
        if n_moe_start:
            dk = jax.random.split(k_dense, n_moe_start)
            params["first_dense"] = [
                _init_layer(dk[i], cfg, "attn", moe=False)
                for i in range(n_moe_start)]
        n_scan = cfg.num_layers - n_moe_start
        keys = jax.random.split(k_layers, n_scan)
        params["layers"] = jax.vmap(
            lambda kk: _init_layer(kk, cfg, "attn",
                                   moe=cfg.num_experts > 0))(keys)
    else:
        keys = jax.random.split(k_layers, cfg.num_layers)
        layers = []
        for i, kind in enumerate(blocks):
            if kind == "shared_attn":
                layers.append({})        # weights live in params["shared_attn"]
            else:
                layers.append(_init_layer(keys[i], cfg, kind,
                                          moe=cfg.is_moe_layer(i)))
        params["layers"] = layers
        if "shared_attn" in blocks:
            params["shared_attn"] = _init_layer(k_shared, cfg, "attn",
                                                moe=False)
    if cfg.encoder_layers:
        ek = jax.random.split(k_enc, cfg.encoder_layers)
        if cfg.scan_layers:
            enc_layers = jax.vmap(
                lambda kk: _init_layer(kk, cfg, "enc_attn", moe=False))(ek)
        else:
            enc_layers = [_init_layer(ek[i], cfg, "enc_attn", moe=False)
                          for i in range(cfg.encoder_layers)]
        params["encoder"] = {
            "layers": enc_layers,
            "final_norm": init_norm(cfg),
        }
    return params


# ---------------------------------------------------------------------------
# train forward
# ---------------------------------------------------------------------------
def _run_encoder(params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """Bidirectional encoder over stub frame embeddings [B, Se, d]."""
    pos = jnp.arange(frames.shape[1])[None, :]

    def body(x, lp):
        def f(x):
            y, _ = _apply_layer_train(lp, cfg, "enc_attn", False, x, pos, None)
            return y
        f = _remat(f, cfg)
        return f(x), None

    enc_layers = params["encoder"]["layers"]
    if isinstance(enc_layers, list):
        x = frames
        for lp in enc_layers:
            x, _ = body(x, lp)
    else:
        x, _ = jax.lax.scan(body, frames, enc_layers)
    return apply_norm(params["encoder"]["final_norm"], x)


def model_hidden_train(params, cfg: ModelConfig, tokens: jnp.ndarray,
                       patch_embeds: Optional[jnp.ndarray] = None,
                       frames: Optional[jnp.ndarray] = None
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token ids -> final hidden states. Returns (h [B,S,d], aux_loss)."""
    x = params["embed"][tokens]
    if patch_embeds is not None:        # VLM: patches replace a prefix
        pcount = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype),
                             x[:, pcount:]], axis=1)
    memory = _run_encoder(params, cfg, frames) if frames is not None else None
    b, s = tokens.shape
    positions = jnp.arange(s)[None, :]
    aux_total = jnp.zeros((), jnp.float32)

    blocks = cfg.blocks
    homogeneous = all(bk == "attn" for bk in blocks) and not cfg.block_pattern
    if homogeneous and cfg.scan_layers:
        for lp in params.get("first_dense", []):
            x, _ = _apply_layer_train(lp, cfg, "attn", False, x, positions,
                                      memory)

        def body(carry, lp):
            x, aux = carry

            def f(x):
                return _apply_layer_train(lp, cfg, "attn",
                                          cfg.num_experts > 0, x, positions,
                                          memory)
            f = _remat(f, cfg)
            x, a = f(x)
            return (x, aux + a), None

        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                         params["layers"])
    else:
        for i, kind in enumerate(blocks):
            lp = (params["shared_attn"] if kind == "shared_attn"
                  else params["layers"][i])
            k = "attn" if kind == "shared_attn" else kind

            def f(x, lp=lp, k=k, i=i):
                return _apply_layer_train(lp, cfg, k, cfg.is_moe_layer(i),
                                          x, positions, memory)
            f = _remat(f, cfg)
            x, a = f(x)
            aux_total = aux_total + a
    return apply_norm(params["final_norm"], x), aux_total


def _head_weight(params) -> jnp.ndarray:
    return params["head"] if "head" in params else params["embed"].T


def chunked_ce_loss(h: jnp.ndarray, w_head: jnp.ndarray,
                    labels: jnp.ndarray, mask: jnp.ndarray,
                    chunk: int = LOSS_CHUNK, unroll: bool = False
                    ) -> jnp.ndarray:
    """Next-token CE without materializing [B, S, V]: scan over S chunks."""
    b, s, d = h.shape
    c = min(chunk, s)
    nc = (s + c - 1) // c
    pad = nc * c - s
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = h.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, c).transpose(1, 0, 2)
    mc = mask.reshape(b, nc, c).transpose(1, 0, 2)

    v = w_head.shape[-1]

    def body(acc, inp):
        hi, li, mi = inp
        logits = (hi @ w_head).astype(jnp.float32)          # [B, c, V]
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via one-hot reduce, NOT take_along_axis: a gather along
        # the vocab axis would force an all-gather of the vocab-sharded
        # logits (~20 GB/chunk at V=152k); the masked sum keeps V sharded
        # and reduces to a [B, c] all-reduce. (EXPERIMENTS.md §Perf #1)
        onehot = li[..., None] == jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, v), 2)
        gold = jnp.sum(logits * onehot, axis=-1)
        nll = (logz - gold) * mi
        return (acc[0] + nll.sum(), acc[1] + mi.sum()), None

    init = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    if unroll:
        acc = init
        for i in range(nc):
            acc, _ = body(acc, (hc[i], lc[i], mc[i]))
        tot, cnt = acc
    else:
        (tot, cnt), _ = jax.lax.scan(body, init, (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def train_loss(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]
               ) -> jnp.ndarray:
    """batch: tokens [B,S], loss_mask [B,S] (+ patch_embeds / frames)."""
    h, aux = model_hidden_train(params, cfg, batch["tokens"],
                                batch.get("patch_embeds"),
                                batch.get("frames"))
    labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
    mask = jnp.pad(batch["loss_mask"][:, 1:], ((0, 0), (0, 1))
                   ).astype(jnp.float32)
    loss = chunked_ce_loss(h, _head_weight(params), labels, mask,
                           unroll=cfg.unroll)
    return loss + cfg.router_aux_weight * aux


def grow_cache(cache: PyTree, target_len: int) -> PyTree:
    """Pad prefill caches ("k"/"v"/"ckv"/"krope", seq axis 1) to target_len
    so decode has growth room. SSM states and encoder memory are untouched."""
    def grow(path, leaf):
        names = {getattr(k, "key", None) for k in path}
        if names & {"k", "v", "ckv", "krope"}:
            # k/v: [(L,)B,S,Hkv,Dh] -> seq axis ndim-3;
            # ckv/krope: [(L,)B,S,R] -> seq axis ndim-2.
            axis = leaf.ndim - 3 if names & {"k", "v"} else leaf.ndim - 2
            pad = [(0, 0)] * leaf.ndim
            pad[axis] = (0, max(0, target_len - leaf.shape[axis]))
            return jnp.pad(leaf, pad)
        return leaf
    return jax.tree_util.tree_map_with_path(grow, cache)


# ---------------------------------------------------------------------------
# serving: cache init + single-token decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               enc_len: int = 0) -> PyTree:
    """Cache pytree matching the layer structure.

    Attention layers: k/v ring buffers [B, S(, ...)]; MLA: compressed c_kv;
    SSM layers: recurrent state. For sliding-window configs the attention
    cache is only ``cfg.window`` long."""
    dt = jnp.dtype(cfg.dtype)
    s_att = min(seq_len, cfg.window) if cfg.attention == "sliding" else seq_len

    def attn_cache():
        if cfg.mla:
            return {"ckv": jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dt),
                    "krope": jnp.zeros((batch, seq_len, cfg.qk_rope_head_dim),
                                       dt)}
        return {"k": jnp.zeros((batch, s_att, cfg.num_kv_heads,
                                cfg.head_dim), dt),
                "v": jnp.zeros((batch, s_att, cfg.num_kv_heads,
                                cfg.head_dim), dt)}

    blocks = cfg.blocks
    homogeneous = all(b == "attn" for b in blocks) and not cfg.block_pattern
    cache: Dict = {}
    if homogeneous and cfg.scan_layers:
        n_scan = cfg.num_layers - (cfg.first_k_dense if cfg.num_experts else 0)
        cache["first_dense"] = [attn_cache() for _ in
                                range(cfg.first_k_dense
                                      if cfg.num_experts else 0)]
        cache["layers"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_scan,) + x.shape).copy(),
            attn_cache())
    else:
        per_layer = []
        for kind in blocks:
            if kind in ("attn", "shared_attn"):
                per_layer.append(attn_cache())
            elif kind == "mlstm":
                per_layer.append(init_mlstm_state(cfg, batch))
            elif kind == "slstm":
                per_layer.append(init_slstm_state(cfg, batch))
            elif kind == "mamba":
                per_layer.append(init_mamba_state(cfg, batch))
        cache["layers"] = per_layer
    if cfg.encoder_layers:
        cache["memory"] = jnp.zeros((batch, enc_len, cfg.d_model), dt)
    return cache


def _decode_attn_layer(lp, cfg, x, c, length, memory):
    h = apply_norm(lp["ln1"], x)
    if cfg.mla:
        out, ckv, krope = mla_decode(lp["attn"], cfg, h, c["ckv"],
                                     c["krope"], length)
        c = {"ckv": ckv, "krope": krope}
    else:
        out, ck, cv = attention_decode(lp["attn"], cfg, h, c["k"], c["v"],
                                       length)
        c = {"k": ck, "v": cv}
    x = x + out
    if "cross" in lp and memory is not None:
        h = apply_norm(lp["ln_cross"], x)
        x = x + cross_attention_train(lp["cross"], cfg, h, memory)
    h = apply_norm(lp["ln2"], x)
    if cfg.num_experts and "router" in lp["ffn"]:
        x = x + moe_decode(lp["ffn"], cfg, h)
    else:
        x = x + ffn_forward(lp["ffn"], cfg, h)
    return x, c


def serve_step(params, cfg: ModelConfig, tokens: jnp.ndarray,
               cache: PyTree, lengths: jnp.ndarray
               ) -> Tuple[jnp.ndarray, PyTree]:
    """Decode ONE token. tokens: [B, 1]; lengths: [B] (current cache fill).

    Returns (logits [B, V], new_cache)."""
    x = params["embed"][tokens]                      # [B, 1, d]
    memory = cache.get("memory") if cfg.encoder_layers else None
    blocks = cfg.blocks
    homogeneous = all(b == "attn" for b in blocks) and not cfg.block_pattern

    if homogeneous and cfg.scan_layers:
        new_fd = []
        for lp, c in zip(params.get("first_dense", []),
                         cache.get("first_dense", [])):
            x, c = _decode_attn_layer(lp, cfg, x, c, lengths, memory)
            new_fd.append(c)

        def body(x, lp_c):
            lp, c = lp_c
            x, c = _decode_attn_layer(lp, cfg, x, c, lengths, memory)
            return x, c

        x, new_cache_layers = jax.lax.scan(body, x,
                                           (params["layers"],
                                            cache["layers"]))
        new_cache = dict(cache)
        new_cache["layers"] = new_cache_layers
        new_cache["first_dense"] = new_fd
    else:
        new_layers = []
        for i, kind in enumerate(blocks):
            lp = (params["shared_attn"] if kind == "shared_attn"
                  else params["layers"][i])
            c = cache["layers"][i]
            if kind in ("attn", "shared_attn"):
                x, c = _decode_attn_layer(lp, cfg, x, c, lengths, memory)
            elif kind == "mlstm":
                h = apply_norm(lp["ln1"], x)
                out, c = mlstm_decode_step(lp["mlstm"], cfg, h, c)
                x = x + out
            elif kind == "slstm":
                h = apply_norm(lp["ln1"], x)
                out, c = slstm_decode_step(lp["slstm"], cfg, h, c)
                x = x + out
            elif kind == "mamba":
                h = apply_norm(lp["ln1"], x)
                out, c = mamba_decode_step(lp["mamba"], cfg, h, c)
                x = x + out
            new_layers.append(c)
        new_cache = dict(cache)
        new_cache["layers"] = new_layers

    h = apply_norm(params["final_norm"], x)
    logits = (h[:, 0] @ _head_weight(params)).astype(jnp.float32)
    return logits, new_cache
