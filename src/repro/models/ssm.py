"""SSM & recurrent blocks: Mamba2 (SSD), xLSTM's mLSTM and sLSTM.

TPU adaptation (DESIGN.md §3): the GPU reference implementations use fused
CUDA scans; here the shared compute core is **chunked linear attention** —
within a chunk the recurrence is unrolled into two MXU matmuls (intra-chunk
masked attention + state read), across chunks a lax.scan carries the state:

    S_t = a_t * S_{t-1} + k_t v_t^T          (per head, a_t scalar decay)
    y_t = q_t^T S_t                           (+ normalizer for mLSTM)

This is exactly Mamba2's SSD duality and GLA-style mLSTM. The sLSTM's
scalar-memory exponential gating is inherently sequential -> lax.scan over
time (it exists in xLSTM precisely to trade parallelism for expressivity;
we keep it faithful and accept the scan).

mLSTM deviation (recorded): the exponential input gate + max-stabilizer of
the paper is replaced by sigmoid gates with a large forget bias — the
stabilizer state does not commute with chunk-parallel form; sigmoid gating
keeps the identical state-update structure and is TPU-stable.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig


# ---------------------------------------------------------------------------
# Chunked linear attention core
# ---------------------------------------------------------------------------
def chunked_linear_attention(q, k, v, log_decay, state: Optional[jnp.ndarray],
                             chunk: int, normalize: bool = False,
                             norm_state: Optional[jnp.ndarray] = None,
                             unroll: bool = False):
    """q,k: [B,S,H,Dk]; v: [B,S,H,Dv]; log_decay: [B,S,H] (<= 0).

    Returns (y [B,S,H,Dv], final_state [B,H,Dk,Dv], final_norm [B,H,Dk]).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    nc = (s + c - 1) // c
    pad = nc * c - s
    if pad:
        zpad = lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] *
                                 (x.ndim - 2))
        q, k, v, log_decay = zpad(q), zpad(k), zpad(v), zpad(log_decay)
        # padded decay 0 => a=1, padded k,v are 0 => state unchanged
    f32 = jnp.float32
    qc = q.reshape(b, nc, c, h, dk).astype(f32)
    kc = k.reshape(b, nc, c, h, dk).astype(f32)
    vc = v.reshape(b, nc, c, h, dv).astype(f32)
    lc = log_decay.reshape(b, nc, c, h).astype(f32)
    if state is None:
        state = jnp.zeros((b, h, dk, dv), f32)
    if norm_state is None:
        norm_state = jnp.zeros((b, h, dk), f32)

    def step(carry, inp):
        S, n = carry
        qi, ki, vi, li = inp                       # [B,c,H,*]
        cum = jnp.cumsum(li, axis=1)               # inclusive [B,c,H]
        total = cum[:, -1]                         # [B,H]
        # intra-chunk: D[i,j] = exp(cum_i - cum_j) for j<=i  (i>j strictly
        # includes a_i..a_{j+1}; j==i -> 1)
        diff = cum[:, :, None, :] - cum[:, None, :, :]     # [B,i,j,H]
        tri = (jnp.arange(c)[:, None] >= jnp.arange(c)[None, :])
        D = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        logits = jnp.einsum("bihd,bjhd->bijh", qi, ki) * D
        y = jnp.einsum("bijh,bjhv->bihv", logits, vi)
        # inter-chunk: read decayed previous state
        decay_i = jnp.exp(cum)                      # [B,c,H]
        y += jnp.einsum("bihd,bhdv->bihv", qi * decay_i[..., None], S)
        # normalizer (mLSTM): n_i = sum_{j<=i} D[i,j] k_j + exp(cum_i) n_prev
        nn = jnp.einsum("bijh,bjhd->bihd", D, ki)
        nn += decay_i[..., None] * n[:, None]
        # state update: S' = exp(total) S + sum_j exp(total - cum_j) k_j v_j^T
        w = jnp.exp(total[:, None] - cum)           # [B,c,H]
        S = jnp.exp(total)[..., None, None] * S + jnp.einsum(
            "bjhd,bjhv->bhdv", kc_w := ki * w[..., None], vi)
        n = jnp.exp(total)[..., None] * n + kc_w.sum(axis=1)
        return (S, n), (y, nn)

    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), lc.transpose(1, 0, 2, 3))
    if unroll:
        carry = (state, norm_state)
        ys_list, ns_list = [], []
        for i in range(nc):
            carry, (yi, ni) = step(carry, jax.tree.map(lambda a: a[i], xs))
            ys_list.append(yi)
            ns_list.append(ni)
        state, norm_state = carry
        ys, ns = jnp.stack(ys_list), jnp.stack(ns_list)
    else:
        (state, norm_state), (ys, ns) = jax.lax.scan(
            step, (state, norm_state), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * c, h, dv)[:, :s]
    if normalize:
        n_full = ns.transpose(1, 0, 2, 3, 4).reshape(b, nc * c, h, dk)[:, :s]
        qn = q.reshape(b, nc * c, h, dk)[:, :s].astype(f32)
        denom = jnp.abs(jnp.einsum("bshd,bshd->bsh", qn, n_full))
        y = y / jnp.maximum(denom, 1.0)[..., None]
    return y, state, norm_state


def linear_attention_step(q, k, v, log_decay, state, norm_state):
    """Single-token recurrent step. q,k: [B,H,Dk]; v: [B,H,Dv];
    log_decay: [B,H]. Returns (y [B,H,Dv], state, norm)."""
    f32 = jnp.float32
    a = jnp.exp(log_decay.astype(f32))[..., None, None]
    state = a * state + jnp.einsum("bhd,bhv->bhdv", k.astype(f32),
                                   v.astype(f32))
    norm_state = a[..., 0] * norm_state + k.astype(f32)
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(f32), state)
    return y, state, norm_state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------
def init_mamba(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    di = 2 * d                       # inner dim (expand=2)
    hd = 64                          # mamba2 head dim
    nh = di // hd
    dstate = cfg.ssm_state_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    s = (1.0 / d) ** 0.5
    return {
        # in_proj -> [z(di), x(di), B(dstate), C(dstate), dt(nh)]
        "w_in": (jax.random.normal(
            ks[0], (d, 2 * di + 2 * dstate + nh)) * s).astype(dt),
        "conv": (jax.random.normal(ks[1], (cfg.conv_kernel,
                                           di + 2 * dstate)) * 0.1).astype(dt),
        "A_log": jnp.zeros((nh,), jnp.float32),      # A = -exp(A_log)
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "w_out": (jax.random.normal(ks[2], (di, d))
                  * (1.0 / di) ** 0.5).astype(dt),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Per-channel causal conv. x: [B, S, C]; w: [K, C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out


def mamba_forward(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                  state: Optional[Dict] = None, return_state: bool = False
                  ) -> Tuple[jnp.ndarray, Dict]:
    """x: [B, S, d]. Returns (y, final_state). SSD chunked path."""
    b, s, d = x.shape
    di = 2 * d
    hd = 64
    nh = di // hd
    dstate = cfg.ssm_state_dim
    proj = x @ p["w_in"]
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * dstate], axis=-1)
    xbc = _causal_conv(xbc, p["conv"])
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [di, di + dstate], axis=-1)
    dt_act = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                          # [nh] negative
    log_a = (dt_act * A).reshape(b, s, nh)            # [B,S,H] <= 0
    v = xs.reshape(b, s, nh, hd) * dt_act.reshape(b, s, nh, 1).astype(x.dtype)
    k = jnp.broadcast_to(Bm[:, :, None, :], (b, s, nh, dstate))
    q = jnp.broadcast_to(Cm[:, :, None, :], (b, s, nh, dstate))
    st = state["ssm"] if state else None
    y, st_new, _ = chunked_linear_attention(q, k, v, log_a, st,
                                            cfg.chunk_size,
                                            unroll=cfg.unroll)
    y = y.reshape(b, s, di).astype(x.dtype) \
        + xs * jnp.repeat(p["D"], hd).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"]
    new_state = {}
    if return_state:
        # decode handoff: final SSM state + last K-1 pre-conv rows
        raw_xbc = proj[..., di:2 * di + 2 * dstate]
        tail = jnp.pad(raw_xbc, ((0, 0), (cfg.conv_kernel - 1, 0),
                                 (0, 0)))[:, -(cfg.conv_kernel - 1):]
        new_state = {"ssm": st_new, "conv": tail}
    return out, new_state


def mamba_decode_step(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                      state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """x: [B, 1, d]; state: {"ssm": [B,H,Dk,Dv], "conv": [B,K-1,C]}."""
    b, _, d = x.shape
    di = 2 * d
    hd = 64
    nh = di // hd
    dstate = cfg.ssm_state_dim
    proj = x @ p["w_in"]                              # [B,1,*]
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * dstate], axis=-1)
    hist = jnp.concatenate([state["conv"], xbc], axis=1)   # [B,K,C]
    conv_out = (hist * p["conv"]).sum(axis=1, keepdims=True)
    xbc = jax.nn.silu(conv_out)
    xs, Bm, Cm = jnp.split(xbc, [di, di + dstate], axis=-1)
    dt_act = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    log_a = dt_act * A                                # [B,nh]
    v = (xs[:, 0].reshape(b, nh, hd)
         * dt_act.reshape(b, nh, 1).astype(x.dtype))
    k = jnp.broadcast_to(Bm[:, 0, None, :], (b, nh, dstate))
    q = jnp.broadcast_to(Cm[:, 0, None, :], (b, nh, dstate))
    y, ssm, _ = linear_attention_step(q, k, v, log_a, state["ssm"],
                                      jnp.zeros((b, nh, dstate)))
    y = y.reshape(b, 1, di).astype(x.dtype) \
        + xs * jnp.repeat(p["D"], hd).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["w_out"], {"ssm": ssm, "conv": hist[:, 1:]}


def init_mamba_state(cfg: ModelConfig, batch: int) -> Dict:
    d = cfg.d_model
    di = 2 * d
    nh = di // 64
    return {"ssm": jnp.zeros((batch, nh, cfg.ssm_state_dim, 64), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1,
                               di + 2 * cfg.ssm_state_dim),
                              jnp.dtype(cfg.dtype))}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM block
# ---------------------------------------------------------------------------
def init_mlstm(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    s = (1.0 / d) ** 0.5
    return {
        "wq": (jax.random.normal(ks[0], (d, d)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, d)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, d)) * s).astype(dt),
        "w_if": (jax.random.normal(ks[3], (d, 2 * h)) * s).astype(dt),
        "b_if": jnp.concatenate([jnp.zeros((h,)),
                                 jnp.full((h,), 4.0)]).astype(jnp.float32),
        "wo_gate": (jax.random.normal(ks[4], (d, d)) * s).astype(dt),
        "w_out": (jax.random.normal(ks[5], (d, d)) * s).astype(dt),
    }


def mlstm_forward(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                  state: Optional[Dict] = None) -> Tuple[jnp.ndarray, Dict]:
    b, s, d = x.shape
    h = cfg.num_heads
    dh = d // h
    q = (x @ p["wq"]).reshape(b, s, h, dh) * dh ** -0.5
    k = (x @ p["wk"]).reshape(b, s, h, dh) * dh ** -0.5
    v = (x @ p["wv"]).reshape(b, s, h, dh)
    gates = (x @ p["w_if"]).astype(jnp.float32) + p["b_if"]
    i_gate = jax.nn.sigmoid(gates[..., :h])                 # [B,S,H]
    log_f = jax.nn.log_sigmoid(gates[..., h:])              # <= 0
    st = state["S"] if state else None
    ns = state["n"] if state else None
    y, S, n = chunked_linear_attention(q, k * i_gate[..., None], v, log_f,
                                       st, cfg.chunk_size, normalize=True,
                                       norm_state=ns, unroll=cfg.unroll)
    y = y.reshape(b, s, d).astype(x.dtype)
    y = y * jax.nn.sigmoid(x @ p["wo_gate"])
    return y @ p["w_out"], {"S": S, "n": n}


def mlstm_decode_step(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                      state: Dict) -> Tuple[jnp.ndarray, Dict]:
    b, _, d = x.shape
    h = cfg.num_heads
    dh = d // h
    q = (x[:, 0] @ p["wq"]).reshape(b, h, dh) * dh ** -0.5
    k = (x[:, 0] @ p["wk"]).reshape(b, h, dh) * dh ** -0.5
    v = (x[:, 0] @ p["wv"]).reshape(b, h, dh)
    gates = (x[:, 0] @ p["w_if"]).astype(jnp.float32) + p["b_if"]
    i_gate = jax.nn.sigmoid(gates[..., :h])
    log_f = jax.nn.log_sigmoid(gates[..., h:])
    y, S, n = linear_attention_step(q, k * i_gate[..., None], v, log_f,
                                    state["S"], state["n"])
    denom = jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n))
    y = (y / jnp.maximum(denom, 1.0)[..., None]).reshape(b, 1, d)
    y = y.astype(x.dtype) * jax.nn.sigmoid(x @ p["wo_gate"])
    return y @ p["w_out"], {"S": S, "n": n}


def init_mlstm_state(cfg: ModelConfig, batch: int) -> Dict:
    h = cfg.num_heads
    dh = cfg.d_model // h
    return {"S": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32)}


# ---------------------------------------------------------------------------
# xLSTM: sLSTM block (sequential scan; faithful exponential gating with
# max-stabilizer state)
# ---------------------------------------------------------------------------
def init_slstm(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    s = (1.0 / d) ** 0.5
    return {
        "w_in": (jax.random.normal(ks[0], (d, 4 * d)) * s).astype(dt),
        # block-diagonal recurrent weights, per head: [H, Dh, 4*Dh]
        "r": (jax.random.normal(ks[1], (h, dh, 4 * dh))
              * (1.0 / dh) ** 0.5).astype(jnp.float32),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "w_out": (jax.random.normal(ks[2], (d, d)) * s).astype(dt),
    }


def _slstm_cell(p, cfg, xt, carry):
    """One sLSTM step. xt: [B, 4d] (pre-projected). carry: dict of [B,H,Dh]."""
    h_prev, c_prev, n_prev, m_prev = (carry["h"], carry["c"], carry["n"],
                                      carry["m"])
    b = xt.shape[0]
    nh = cfg.num_heads
    dh = cfg.d_model // nh
    rec = jnp.einsum("bhd,hdk->bhk", h_prev, p["r"])        # [B,H,4*Dh]
    pre = (xt.reshape(b, nh, 4 * dh).astype(jnp.float32) + rec
           + p["bias"].reshape(nh, 4 * dh))
    z, i_raw, f_raw, o_raw = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o_raw)
    log_i = i_raw                                           # exp input gate
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + m_prev, log_i)
    i_st = jnp.exp(log_i - m_new)
    f_st = jnp.exp(log_f + m_prev - m_new)
    c_new = f_st * c_prev + i_st * z
    n_new = f_st * n_prev + i_st
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def slstm_forward(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                  state: Optional[Dict] = None) -> Tuple[jnp.ndarray, Dict]:
    b, s, d = x.shape
    nh = cfg.num_heads
    dh = d // nh
    if state is None:
        state = init_slstm_state(cfg, b)
    xin = x @ p["w_in"]                                     # [B,S,4d]

    def step(carry, xt):
        new = _slstm_cell(p, cfg, xt, carry)
        return new, new["h"]

    state, hs = jax.lax.scan(step, state, xin.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    return y @ p["w_out"], state


def slstm_decode_step(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                      state: Dict) -> Tuple[jnp.ndarray, Dict]:
    xin = x[:, 0] @ p["w_in"]
    new = _slstm_cell(p, cfg, xin, state)
    b = x.shape[0]
    y = new["h"].reshape(b, 1, cfg.d_model).astype(x.dtype)
    return y @ p["w_out"], new


def init_slstm_state(cfg: ModelConfig, batch: int) -> Dict:
    nh = cfg.num_heads
    dh = cfg.d_model // nh
    shape = (batch, nh, dh)
    return {"h": jnp.zeros(shape, jnp.float32),
            "c": jnp.zeros(shape, jnp.float32),
            "n": jnp.zeros(shape, jnp.float32),
            "m": jnp.full(shape, -1e30, jnp.float32)}
