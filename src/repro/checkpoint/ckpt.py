"""Pytree checkpointing: msgpack index + raw .npy payloads.

Sharding-aware in the practical sense: arrays are pulled to host with
``jax.device_get`` (which assembles a fully-addressable global view) and, on
restore, the caller re-applies shardings via ``jax.device_put`` with the
current mesh. Layout: ``<dir>/step_<n>/{manifest.msgpack, arr_<i>.npy}``.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import msgpack
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree: PyTree) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    flat, treedef = _flatten_with_paths(tree)
    host = jax.device_get(flat)
    manifest = {"treedef": str(treedef), "num": len(host), "step": step}
    for i, arr in enumerate(host):
        np.save(os.path.join(path, f"arr_{i}.npy"), np.asarray(arr))
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like: PyTree,
                       step: Optional[int] = None) -> PyTree:
    """Restore into the structure of ``like`` (shapes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    flat, treedef = _flatten_with_paths(like)
    if manifest["num"] != len(flat):
        raise ValueError(f"checkpoint has {manifest['num']} leaves, "
                         f"expected {len(flat)}")
    loaded = []
    for i, ref in enumerate(flat):
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {np.shape(ref)}")
        loaded.append(arr)
    return jax.tree_util.tree_unflatten(treedef, loaded)
