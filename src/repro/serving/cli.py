"""CLI for the serving subsystem (DESIGN.md §13).

    # end-to-end: export (or reuse) a 4-partition pipeline bundle, replay a
    # 10k-query Zipf workload through the continuous batcher, verify served
    # labels against the offline answer key, append BENCH_serving.json
    PYTHONPATH=src python -m repro.serving

    # multi-process layout (the DGL server/client shape, SNIPPETS §2):
    PYTHONPATH=src python -m repro.serving serve  --port 7431 &
    PYTHONPATH=src python -m repro.serving client --port 7431 --queries 2000

The server hosts the partition-sharded store behind one continuous batcher;
any number of clients connect concurrently (batching happens *across*
connections — that is the point of continuous batching). The line protocol
is JSON per line: ``{"op": "query", "node": 17}``,
``{"op": "query", "node": 99999, "neighbors": [3, 14, 15]}`` (inductive),
``{"op": "meta"}``, ``{"op": "stats"}``.

Bundles are keyed by the partitioner-spec fingerprint: a bundle exported
under different partitioner hyperparameters is a *hard error*
(:class:`repro.serving.store.StaleServingArtifact`), never silently served.
"""
from __future__ import annotations

import argparse
import json
import logging
import os
import socket
import socketserver
import sys
import threading
import time
from typing import List, Optional

log = logging.getLogger("repro.serving")

DEFAULT_BUNDLE_DIR = os.path.join("~", ".cache", "repro", "serving")
DEFAULT_CACHE = os.path.join("~", ".cache", "repro", "partitions")


# ---------------------------------------------------------------------------
# argparse
# ---------------------------------------------------------------------------
def _add_bundle_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--bundle-dir", default=DEFAULT_BUNDLE_DIR,
                    help="directory of serving bundles (fingerprint-named)")
    ap.add_argument("--bundle", default=None,
                    help="explicit bundle .npz (skips the pipeline export)")
    ap.add_argument("--dataset", default="arxiv-like")
    ap.add_argument("--nodes", type=int, default=2000,
                    help="synthetic dataset size for the export pipeline")
    ap.add_argument("--method", default="leiden_fusion",
                    help="partitioner spec; its config fingerprint keys "
                         "the bundle — mismatches are hard errors")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--classifier-epochs", type=int, default=80)
    ap.add_argument("--hidden-dim", type=int, default=64)
    ap.add_argument("--embed-dim", type=int, default=64)
    ap.add_argument("--cache-dir", default=DEFAULT_CACHE,
                    help="partition artifact cache for the export pipeline")
    ap.add_argument("--rebuild", action="store_true",
                    help="re-run the pipeline even if a bundle exists")


def _add_batcher_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--cache-capacity", type=int, default=512,
                    help="LRU hot-node cache size (embedding rows)")
    ap.add_argument("--max-neighbors", type=int, default=32,
                    help="inductive fallback: neighbor-axis pad size")
    ap.add_argument("--use-kernel", action="store_true",
                    help="inductive aggregation through the Pallas kernel "
                         "(DESIGN.md §11) instead of the jnp segment-sum")


def _add_workload_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--queries", type=int, default=10_000)
    ap.add_argument("--alpha", type=float, default=1.1,
                    help="Zipf exponent of the node popularity law")
    ap.add_argument("--unseen-frac", type=float, default=0.02,
                    help="fraction of queries for nodes outside the store "
                         "(answered by the inductive fallback)")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serving",
        description="partition-sharded embedding serving: continuous "
                    "batching + LRU cache + inductive fallback")
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("replay", help="in-process Zipf replay (default)")
    _add_bundle_args(rp)
    _add_batcher_args(rp)
    _add_workload_args(rp)
    rp.add_argument("--bench-json", default=None,
                    help="BENCH trajectory path (default benchmarks/"
                         "artifacts/BENCH_serving.json; 'none' to skip)")
    rp.add_argument("--no-verify", action="store_true",
                    help="skip the exact-match check against the offline "
                         "answer key")
    rp.add_argument("--json", action="store_true")

    sv = sub.add_parser("serve", help="host the store behind a TCP server")
    _add_bundle_args(sv)
    _add_batcher_args(sv)
    sv.add_argument("--host", default="127.0.0.1")
    sv.add_argument("--port", type=int, default=7431)

    cl = sub.add_parser("client", help="replay a workload against a server")
    _add_workload_args(cl)
    cl.add_argument("--host", default="127.0.0.1")
    cl.add_argument("--port", type=int, default=7431)
    cl.add_argument("--concurrency", type=int, default=8,
                    help="parallel connections (batching happens across "
                         "them on the server)")
    cl.add_argument("--seed", type=int, default=0)
    cl.add_argument("--json", action="store_true")
    return ap


# ---------------------------------------------------------------------------
# bundle resolution (export-on-miss through the pipeline)
# ---------------------------------------------------------------------------
def ensure_bundle(args) -> str:
    """Resolve the serving bundle, exporting one via the pipeline on miss.

    Returns the bundle path; the caller loads it with
    ``expect_fingerprint`` so a stale bundle can never be served."""
    from repro.core import PartitionerSpec
    fp = PartitionerSpec.parse(args.method).fingerprint()
    if args.bundle:
        return args.bundle
    bundle_dir = os.path.expanduser(args.bundle_dir)
    cand = os.path.join(bundle_dir, f"serving-{fp}.npz")
    if os.path.exists(cand) and not args.rebuild:
        log.info("serving bundle HIT: %s", cand)
        return cand
    log.info("serving bundle MISS: running the export pipeline "
             "(dataset=%s n=%d k=%d)", args.dataset, args.nodes, args.k)
    from repro.pipeline import Pipeline, PipelineConfig
    dataset_kwargs = {}
    if args.dataset.replace("-", "_") != "karate":
        dataset_kwargs["n"] = args.nodes
    cfg = PipelineConfig(
        dataset=args.dataset, method=args.method, k=args.k, seed=args.seed,
        mode="local", hidden_dim=args.hidden_dim, embed_dim=args.embed_dim,
        epochs=args.epochs, classifier_epochs=args.classifier_epochs,
        cache_dir=args.cache_dir, collect_hlo=False,
        serving_dir=bundle_dir, dataset_kwargs=dataset_kwargs)
    report = Pipeline(cfg).run()
    log.info("exported serving bundle: %s (test acc %.3f)",
             report.serving_path, report.accuracy.get("test", float("nan")))
    return report.serving_path


def load_store(args):
    from repro.core import PartitionerSpec
    from .store import EmbeddingStore
    path = ensure_bundle(args)
    fp = PartitionerSpec.parse(args.method).fingerprint() \
        if not args.bundle else None
    return EmbeddingStore.load(path, expect_fingerprint=fp)


def make_batcher(store, args):
    from .batcher import ContinuousBatcher
    from .cache import LruNodeCache
    return ContinuousBatcher(
        store, cache=LruNodeCache(args.cache_capacity),
        max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
        max_neighbors=args.max_neighbors, use_kernel=args.use_kernel)


# ---------------------------------------------------------------------------
# replay (the default command — the end-to-end acceptance path)
# ---------------------------------------------------------------------------
def cmd_replay(args) -> int:
    from .replay import (DEFAULT_BENCH_JSON, append_bench_rows,
                         make_zipf_workload, run_replay)
    store = load_store(args)
    log.info("%s", store.summary())
    batcher = make_batcher(store, args)
    workload = make_zipf_workload(
        store.n, num_queries=args.queries, alpha=args.alpha,
        unseen_frac=args.unseen_frac, max_neighbors=args.max_neighbors,
        seed=args.seed)
    row = run_replay(batcher, workload, verify=not args.no_verify)
    bench = args.bench_json or DEFAULT_BENCH_JSON
    if bench != "none":
        append_bench_rows([row], path=bench)
        log.info("BENCH row appended: %s", bench)
    if args.json:
        print(json.dumps(row, indent=2))
    else:
        srcs = ", ".join(f"{k}={v}" for k, v in
                         sorted(row["served_by_source"].items()))
        print(f"serving replay: {row['queries']} queries in "
              f"{row['wall_s']}s ({row['throughput_qps']} qps)")
        print(f"  latency      p50={row['p50_ms']}ms p99={row['p99_ms']}ms")
        print(f"  cache        hit_rate={row['cache_hit_rate']}")
        print(f"  compiles     warm={row['warm_compiles']} "
              f"steady_state={row['steady_state_recompiles']}")
        reasons = ", ".join(f"{k}={v}" for k, v in
                            sorted(row["flush_reasons"].items()))
        print(f"  flushes      {row['flushes']} ({reasons})")
        print(f"  answers      {srcs}")
        print(f"  exact-match  {row['queries'] - row['label_mismatches']}"
              f"/{row['queries']} (mismatches={row['label_mismatches']})")
    return 0


# ---------------------------------------------------------------------------
# serve / client (multi-process, SNIPPETS §2 shape)
# ---------------------------------------------------------------------------
class _ServingState:
    """Shared batcher + answer dispatch for the threaded TCP server."""

    def __init__(self, store, batcher):
        self.store = store
        self.batcher = batcher
        self.lock = threading.Lock()
        self.answers = {}
        self.events = {}
        self.closing = threading.Event()

    def submit_and_wait(self, node, neighbors, timeout=60.0):
        ev = threading.Event()
        with self.lock:
            qid = self.batcher.submit(node, neighbors=neighbors)
            self.events[qid] = ev
        if not ev.wait(timeout):
            raise TimeoutError(f"query {qid} timed out")
        with self.lock:
            return self.answers.pop(qid)

    def pump_loop(self):
        tick = max(self.batcher.max_wait_ms / 1000.0 / 4, 1e-4)
        while not self.closing.is_set():
            with self.lock:
                ready = self.batcher.pump()
                events = []
                for a in ready:
                    self.answers[a.qid] = a
                    ev = self.events.pop(a.qid, None)
                    if ev is not None:
                        events.append(ev)
            for ev in events:        # wake waiters outside the lock
                ev.set()
            self.closing.wait(tick)


def _serving_state_pump(state: _ServingState) -> None:
    state.pump_loop()


def cmd_serve(args) -> int:
    store = load_store(args)
    batcher = make_batcher(store, args)
    warmed = batcher.warmup()
    state = _ServingState(store, batcher)

    class Handler(socketserver.StreamRequestHandler):
        def handle(self):
            for raw in self.rfile:
                try:
                    req = json.loads(raw)
                except ValueError:
                    self._reply({"error": "bad json"})
                    continue
                op = req.get("op", "query")
                if op == "meta":
                    self._reply({"n": store.n, "k": store.k,
                                 "num_classes": store.num_classes,
                                 "embed_dim": store.embed_dim,
                                 "fingerprint": store.fingerprint})
                elif op == "stats":
                    with state.lock:
                        self._reply(batcher.stats())
                elif op == "query":
                    a = state.submit_and_wait(int(req["node"]),
                                              req.get("neighbors"))
                    self._reply({"id": req.get("id"), "node": a.node_id,
                                 "label": a.label, "shard": a.shard,
                                 "source": a.source,
                                 "latency_ms": round(a.latency_ms, 3)})
                else:
                    self._reply({"error": f"unknown op {op!r}"})

        def _reply(self, obj):
            self.wfile.write((json.dumps(obj) + "\n").encode())
            self.wfile.flush()

    srv = socketserver.ThreadingTCPServer((args.host, args.port), Handler)
    srv.daemon_threads = True
    pump = threading.Thread(target=_serving_state_pump, args=(state,),
                            daemon=True)
    pump.start()
    print(f"serving {store.summary()}")
    print(f"listening on {args.host}:{args.port} "
          f"(warmup compiled {warmed} bucket shapes; ctrl-c to stop)")
    sys.stdout.flush()
    try:
        srv.serve_forever(poll_interval=0.2)
    except KeyboardInterrupt:
        pass
    finally:
        state.closing.set()
        srv.server_close()
    return 0


def cmd_client(args) -> int:
    from .replay import make_zipf_workload

    def _rpc(sock_file, wfile, obj):
        wfile.write((json.dumps(obj) + "\n").encode())
        wfile.flush()
        return json.loads(sock_file.readline())

    with socket.create_connection((args.host, args.port), timeout=60) as s:
        rf, wf = s.makefile("rb"), s.makefile("wb")
        meta = _rpc(rf, wf, {"op": "meta"})
    workload = make_zipf_workload(
        int(meta["n"]), num_queries=args.queries, alpha=args.alpha,
        unseen_frac=args.unseen_frac, seed=args.seed)
    shards = [workload[i::args.concurrency]
              for i in range(args.concurrency)]
    lats: List[List[float]] = [[] for _ in shards]
    by_source: List[dict] = [{} for _ in shards]

    def worker(wi: int):
        with socket.create_connection((args.host, args.port),
                                      timeout=60) as s:
            rf, wf = s.makefile("rb"), s.makefile("wb")
            for node, nbs in shards[wi]:
                req = {"op": "query", "id": wi, "node": int(node)}
                if nbs is not None:
                    req["neighbors"] = [int(x) for x in nbs]
                t0 = time.perf_counter()
                resp = _rpc(rf, wf, req)
                lats[wi].append((time.perf_counter() - t0) * 1000.0)
                src = resp.get("source", "?")
                by_source[wi][src] = by_source[wi].get(src, 0) + 1

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(args.concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    import numpy as np
    flat = np.asarray([x for ls in lats for x in ls])
    merged: dict = {}
    for d in by_source:
        for k, v in d.items():
            merged[k] = merged.get(k, 0) + v
    out = {"queries": int(flat.size), "wall_s": round(wall, 3),
           "throughput_qps": round(flat.size / max(wall, 1e-9), 1),
           "p50_ms": round(float(np.percentile(flat, 50)), 3),
           "p99_ms": round(float(np.percentile(flat, 99)), 3),
           "served_by_source": merged,
           "concurrency": args.concurrency,
           "server": f"{args.host}:{args.port}",
           "fingerprint": meta["fingerprint"]}
    print(json.dumps(out, indent=2) if args.json else
          f"client: {out['queries']} queries, {out['throughput_qps']} qps, "
          f"p50={out['p50_ms']}ms p99={out['p99_ms']}ms, "
          f"sources={merged}")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    logging.basicConfig(level=logging.INFO,
                        format="%(levelname)s %(name)s: %(message)s")
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        argv = ["replay"]        # `python -m repro.serving` end-to-end
    args = build_parser().parse_args(argv)
    if args.cmd == "replay":
        return cmd_replay(args)
    if args.cmd == "serve":
        return cmd_serve(args)
    return cmd_client(args)


if __name__ == "__main__":
    sys.exit(main())
