"""Continuous-batching query loop (DESIGN.md §13).

The serving analogue of the prefill→decode micro-batch loop in
``src/repro/launch/serve.py``: queries accumulate in a queue and flush as
one micro-batch when either ``max_batch`` queries are waiting or the oldest
has waited ``max_wait_ms`` — the standard continuous-batching contract.

Every flush routes queries by partition label: known nodes gather their
embedding from the owning shard (through the LRU hot-node cache) and run
the trained classifier MLP; unknown nodes take the inductive fallback
(:mod:`repro.serving.inductive`) on the shard owning most of their
neighbors.

**Zero-recompile discipline.** Device calls happen at *fixed bucket
shapes*: a flush of ``b`` queries pads to the next power of two ≤
``max_batch``, and the inductive path additionally fixes the neighbor axis
at ``max_neighbors``. ``warmup()`` pre-compiles every bucket once; after
that, a steady-state flush can never introduce a new shape, which
:class:`CompileLog` verifies by watching the jit caches — the
``steady_state_recompiles`` counter the serving benchmark gates on is a
measurement, not an assumption.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import obs

from .cache import LruNodeCache
from .inductive import InductiveEngine

__all__ = ["Query", "Answer", "CompileLog", "ContinuousBatcher",
           "bucket_sizes", "bucket_of"]


def bucket_sizes(max_batch: int) -> Tuple[int, ...]:
    """Power-of-two flush buckets: 1, 2, 4, ..., max_batch."""
    out = []
    b = 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(out)


def bucket_of(n: int, max_batch: int) -> int:
    """Smallest bucket holding ``n`` queries."""
    for b in bucket_sizes(max_batch):
        if n <= b:
            return b
    return max_batch


@dataclasses.dataclass
class Query:
    qid: int
    node_id: int
    neighbors: Optional[np.ndarray]     # only for unknown nodes
    t_submit: float


@dataclasses.dataclass
class Answer:
    qid: int
    node_id: int
    label: int
    shard: int
    source: str           # "cache" | "store" | "inductive" | "degraded"
    latency_ms: float
    logits: Optional[np.ndarray] = None
    embedding: Optional[np.ndarray] = None


class CompileLog:
    """Measured compile counts per jitted callable, split warmup/steady.

    Reads each function's jit cache size around the call (``_cache_size``),
    falling back to a seen-shape set when the private API is unavailable —
    either way the count reflects what XLA actually compiled."""

    def __init__(self):
        self.warm_compiles: Dict[str, int] = {}
        self.steady_compiles: Dict[str, int] = {}
        self._steady = False
        self._shapes: Dict[str, set] = {}

    def mark_steady(self) -> None:
        """End of warmup: every compile from here on is a violation."""
        self._steady = True

    def _cache_size(self, fn) -> Optional[int]:
        try:
            return fn._cache_size()
        except AttributeError:
            return None

    def call(self, name: str, fn: Callable, *args, **kwargs):
        before = self._cache_size(fn)
        out = fn(*args, **kwargs)
        after = self._cache_size(fn)
        if before is not None and after is not None:
            compiled = after - before
        else:   # fallback: infer from the argument shapes
            shapes = tuple(getattr(a, "shape", None) for a in args)
            seen = self._shapes.setdefault(name, set())
            compiled = 0 if shapes in seen else 1
            seen.add(shapes)
        if compiled:
            book = (self.steady_compiles if self._steady
                    else self.warm_compiles)
            book[name] = book.get(name, 0) + compiled
            phase = "steady" if self._steady else "warm"
            obs.counter(f"serving.compiles.{phase}").inc(compiled)
        return out

    @property
    def steady_state_recompiles(self) -> int:
        return sum(self.steady_compiles.values())

    def stats(self) -> Dict[str, Any]:
        return {"warm_compiles": dict(self.warm_compiles),
                "steady_compiles": dict(self.steady_compiles),
                "steady_state_recompiles": self.steady_state_recompiles}


class ContinuousBatcher:
    """max_batch/max_wait_ms flush loop over a sharded embedding store."""

    def __init__(self, store, cache: Optional[LruNodeCache] = None,
                 max_batch: int = 64, max_wait_ms: float = 2.0,
                 max_neighbors: int = 32, use_kernel: bool = False,
                 now: Callable[[], float] = time.perf_counter):
        from repro.gnn import mlp_forward
        self.store = store
        self.cache = cache if cache is not None else LruNodeCache()
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.now = now
        self.inductive = InductiveEngine(store, max_neighbors=max_neighbors,
                                         use_kernel=use_kernel)
        self.compiles = CompileLog()
        self._classify = jax.jit(mlp_forward)
        self._queue: deque[Query] = deque()
        self._next_qid = 0
        self.flushes = 0
        self.queries_served = 0
        self.per_shard_served: Dict[int, int] = {}
        self.flush_reasons: Dict[str, int] = {}

    # ----- intake ---------------------------------------------------------
    def submit(self, node_id: int, neighbors=None,
               now: Optional[float] = None) -> int:
        qid = self._next_qid
        self._next_qid += 1
        nb = None
        if neighbors is not None:
            nb = np.asarray(neighbors, dtype=np.int64).reshape(-1)
        self._queue.append(Query(qid=qid, node_id=int(node_id), neighbors=nb,
                                 t_submit=self.now() if now is None else now))
        return qid

    def pending(self) -> int:
        return len(self._queue)

    # ----- flush policy ---------------------------------------------------
    def due(self, now: Optional[float] = None) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.max_batch:
            return True
        now = self.now() if now is None else now
        return (now - self._queue[0].t_submit) * 1000.0 >= self.max_wait_ms

    def pump(self, now: Optional[float] = None) -> List[Answer]:
        """Flush as long as a flush is due; the serving loop's heartbeat."""
        out: List[Answer] = []
        while self.due(now):
            reason = ("max_batch" if len(self._queue) >= self.max_batch
                      else "max_wait_ms")
            out.extend(self.flush(reason))
        return out

    def drain(self) -> List[Answer]:
        """Flush everything regardless of the policy (end of a replay)."""
        out: List[Answer] = []
        while self._queue:
            out.extend(self.flush("drain"))
        return out

    # ----- the micro-batch ------------------------------------------------
    def warmup(self) -> int:
        """Pre-compile every bucket shape; returns the number of compiles.

        After ``warmup()`` the steady state must never compile again —
        ``compiles.steady_state_recompiles`` counts violations."""
        e = self.store.embed_dim
        clf = {k: np.asarray(v) for k, v in self.store.classifier.items()}
        for b in bucket_sizes(self.max_batch):
            self.compiles.call("classify", self._classify, clf,
                               np.zeros((b, e), np.float32))
            self.compiles.call(
                "inductive", self.inductive.jitted,
                np.zeros((b, self.inductive.max_neighbors, e), np.float32),
                np.zeros((b, self.inductive.max_neighbors), np.float32),
                np.zeros((b, e, self.store.num_classes), np.float32),
                np.zeros((b, self.store.num_classes), np.float32),
                max_neighbors=self.inductive.max_neighbors,
                use_kernel=self.inductive.use_kernel,
                kernel_config=self.inductive.kernel_config(b))
        warmed = sum(self.compiles.warm_compiles.values())
        self.compiles.mark_steady()
        return warmed

    def flush(self, reason: str = "drain") -> List[Answer]:
        batch = [self._queue.popleft()
                 for _ in range(min(self.max_batch, len(self._queue)))]
        if not batch:
            return []
        self.flushes += 1
        self.flush_reasons[reason] = self.flush_reasons.get(reason, 0) + 1
        obs.counter(f"serving.flush.{reason}").inc()
        obs.histogram("serving.batch_size").record(len(batch))
        known = [q for q in batch if self.store.is_known(q.node_id)]
        unknown = [q for q in batch if not self.store.is_known(q.node_id)]
        answers: List[Answer] = []
        with obs.span("serving.flush", reason=reason, batch=len(batch),
                      known=len(known), unknown=len(unknown)):
            answers.extend(self._flush_known(known))
            answers.extend(self._flush_inductive(unknown))
        self.queries_served += len(answers)
        return answers

    def _flush_known(self, queries: List[Query]) -> List[Answer]:
        if not queries:
            return []
        e = self.store.embed_dim
        b_pad = bucket_of(len(queries), self.max_batch)
        obs.counter(f"serving.bucket.classify.{b_pad}").inc()
        emb = np.zeros((b_pad, e), dtype=np.float32)
        sources: List[str] = []
        miss_pos: List[int] = []
        miss_ids: List[int] = []
        for i, q in enumerate(queries):
            row = self.cache.get(q.node_id)
            if row is None:
                miss_pos.append(i)
                miss_ids.append(q.node_id)
                sources.append("store")
            else:
                emb[i] = row
                sources.append("cache")
        if miss_ids:
            rows = self.store.lookup(np.asarray(miss_ids))  # shard-routed
            for pos, nid, row in zip(miss_pos, miss_ids, rows):
                emb[pos] = row
                self.cache.put(nid, row)
        clf = self.store.classifier
        logits = np.asarray(self.compiles.call(
            "classify", self._classify, clf, emb))
        labels = logits[:len(queries)].argmax(-1)
        t_done = self.now()
        out = []
        for i, q in enumerate(queries):
            pid = int(self.store.partition_of[q.node_id])
            self.per_shard_served[pid] = self.per_shard_served.get(pid, 0) + 1
            out.append(Answer(
                qid=q.qid, node_id=q.node_id, label=int(labels[i]),
                shard=pid, source=sources[i],
                latency_ms=(t_done - q.t_submit) * 1000.0,
                logits=logits[i], embedding=emb[i]))
        return out

    def _flush_inductive(self, queries: List[Query]) -> List[Answer]:
        if not queries:
            return []
        b_pad = bucket_of(len(queries), self.max_batch)
        obs.counter(f"serving.bucket.inductive.{b_pad}").inc()
        nb_lists = [q.neighbors if q.neighbors is not None
                    else np.zeros(0, np.int64) for q in queries]
        nb_emb, nb_mask, pids = self.inductive.prepare(nb_lists, b_pad)
        emb, logits = self.compiles.call(
            "inductive", self.inductive.jitted,
            nb_emb, nb_mask,
            self.store.head_w[pids], self.store.head_b[pids],
            max_neighbors=self.inductive.max_neighbors,
            use_kernel=self.inductive.use_kernel,
            kernel_config=self.inductive.kernel_config(b_pad))
        emb, logits = np.asarray(emb), np.asarray(logits)
        degraded = nb_mask.sum(axis=1) == 0
        labels = logits[:len(queries)].argmax(-1)
        t_done = self.now()
        out = []
        for i, q in enumerate(queries):
            pid = int(pids[i])
            self.per_shard_served[pid] = self.per_shard_served.get(pid, 0) + 1
            out.append(Answer(
                qid=q.qid, node_id=q.node_id, label=int(labels[i]),
                shard=pid,
                source="degraded" if degraded[i] else "inductive",
                latency_ms=(t_done - q.t_submit) * 1000.0,
                logits=logits[i], embedding=emb[i]))
        return out

    # ----- reporting ------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        return {
            "flushes": self.flushes,
            "flush_reasons": dict(sorted(self.flush_reasons.items())),
            "queries_served": self.queries_served,
            "max_batch": self.max_batch,
            "max_wait_ms": self.max_wait_ms,
            "buckets": list(bucket_sizes(self.max_batch)),
            "per_shard_served": {str(k): v for k, v in
                                 sorted(self.per_shard_served.items())},
            "cache": self.cache.stats(),
            **self.compiles.stats(),
        }
