"""Partition-sharded embedding store (DESIGN.md §13).

The serving counterpart of the training-side artifact cache: one pipeline
run exports a **serving bundle** — pooled node embeddings, the trained
classifier MLP, the k per-partition GNN heads, and the partition assignment
— as a single content-addressed ``.npz``; :class:`EmbeddingStore` loads it
back as k :class:`ShardStore` shards plus a routing table.

Two fingerprints guard staleness, both hard errors at load time:

* the **partition fingerprint** (the spec config fingerprint that also keys
  the training artifact cache, DESIGN.md §9) — a bundle exported from a
  differently-parameterized partitioner never serves a query;
* the **graph fingerprint** (topology hash, ``repro.pipeline.datasets.
  graph_fingerprint``) when the caller has the graph in hand.

Lookups are *sharded*: the global embedding table is never materialized at
load time — node ids route through ``partition_of`` to their owning shard
and gather from that shard's local rows, exactly how a multi-host
deployment would fan queries out (SNIPPETS §2 is the DGL shape).
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any, Dict, List, Optional

import numpy as np

__all__ = ["SERVING_VERSION", "StaleServingArtifact", "ShardStore",
           "EmbeddingStore", "export_serving_bundle", "export_from_pipeline"]

SERVING_VERSION = 1


class StaleServingArtifact(RuntimeError):
    """A serving bundle whose fingerprints do not match the request.

    Serving from a stale bundle silently answers with embeddings of a
    *different* partitioning/graph, so any mismatch is a hard error — the
    caller must re-export, never degrade."""


# ---------------------------------------------------------------------------
# Export
# ---------------------------------------------------------------------------
def _atomic_savez(path: str, **arrays) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez_compressed(f, **arrays)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def export_serving_bundle(directory: str, *, part_labels: np.ndarray,
                          embeddings: np.ndarray, predictions: np.ndarray,
                          head_w: np.ndarray, head_b: np.ndarray,
                          classifier: Dict[str, Any],
                          meta: Dict[str, Any]) -> str:
    """Write one serving bundle under ``directory``; returns its path.

    The filename embeds the partition fingerprint so differently-partitioned
    exports coexist; the write is atomic (tmp + ``os.replace``)."""
    meta = {"kind": "serving", "version": SERVING_VERSION, **meta}
    fp = meta.get("partition_fingerprint") or "nofp"
    path = os.path.join(directory, f"serving-{fp}.npz")
    _atomic_savez(
        path,
        meta_json=np.asarray(json.dumps(meta, sort_keys=True)),
        part_labels=np.asarray(part_labels, np.int32),
        embeddings=np.asarray(embeddings, np.float32),
        predictions=np.asarray(predictions, np.int32),
        head_w=np.asarray(head_w, np.float32),
        head_b=np.asarray(head_b, np.float32),
        **{f"clf_{k}": np.asarray(v, np.float32)
           for k, v in classifier.items()})
    return path


def export_from_pipeline(directory: str, *, ds, bundle, params,
                         classifier, embeddings: np.ndarray,
                         extra_meta: Optional[Dict[str, Any]] = None) -> str:
    """The pipeline's export hook (called from ``Pipeline.run`` when
    ``serving_dir`` is set): derives predictions/heads/meta from the run's
    live objects and writes the bundle.

    ``predictions`` is the offline answer key — argmax of the trained
    classifier over the full pooled table — which the replay client checks
    served labels against, exactly.
    """
    import jax.numpy as jnp
    from repro.gnn import mlp_forward
    from repro.pipeline.datasets import graph_fingerprint

    if classifier is None:
        raise ValueError("serving export needs the trained classifier — "
                         "run with classifier_epochs > 0")
    logits = np.asarray(mlp_forward(classifier, jnp.asarray(embeddings)))
    predictions = logits.argmax(-1).astype(np.int32)
    head = params["head"]
    meta = {
        "partition_fingerprint": bundle.fingerprint,
        "spec": bundle.spec,
        "graph": graph_fingerprint(ds.graph),
        "dataset": ds.name,
        "n": int(ds.graph.n),
        "k": int(bundle.batch.k),
        "num_classes": int(ds.num_classes),
        "embed_dim": int(embeddings.shape[1]),
        **(extra_meta or {}),
    }
    return export_serving_bundle(
        directory,
        part_labels=bundle.labels,
        embeddings=embeddings,
        predictions=predictions,
        head_w=np.asarray(head["w"]),
        head_b=np.asarray(head["b"]),
        classifier={k: np.asarray(v) for k, v in classifier.items()},
        meta=meta)


# ---------------------------------------------------------------------------
# Load / lookup
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardStore:
    """One partition's slice of the store: owned rows + that partition's
    trained GNN head (the inductive fallback runs it, DESIGN.md §13)."""
    pid: int
    node_ids: np.ndarray       # [m] global ids owned by this shard (sorted)
    embeddings: np.ndarray     # [m, E] rows aligned with node_ids
    head_w: np.ndarray         # [E, C]
    head_b: np.ndarray         # [C]

    @property
    def num_nodes(self) -> int:
        return int(self.node_ids.shape[0])


class EmbeddingStore:
    """k-sharded read view of one serving bundle."""

    def __init__(self, meta: Dict[str, Any], part_labels: np.ndarray,
                 embeddings: np.ndarray, predictions: np.ndarray,
                 head_w: np.ndarray, head_b: np.ndarray,
                 classifier: Dict[str, np.ndarray]):
        self.meta = meta
        self.n = int(part_labels.shape[0])
        self.k = int(head_w.shape[0])
        self.embed_dim = int(embeddings.shape[1])
        self.num_classes = int(head_w.shape[2])
        self.partition_of = part_labels.astype(np.int32)
        self.predictions = predictions.astype(np.int32)
        self.classifier = classifier
        # shard the flat table: local row index per global node
        self._local_row = np.zeros(self.n, dtype=np.int64)
        self.shards: List[ShardStore] = []
        for p in range(self.k):
            owned = np.where(self.partition_of == p)[0]
            self._local_row[owned] = np.arange(owned.shape[0])
            self.shards.append(ShardStore(
                pid=p, node_ids=owned,
                embeddings=np.ascontiguousarray(embeddings[owned]),
                head_w=head_w[p], head_b=head_b[p]))
        self.head_w = head_w        # [k, E, C] (inductive engine gathers)
        self.head_b = head_b        # [k, C]

    # ----- construction ---------------------------------------------------
    @classmethod
    def load(cls, path: str, expect_fingerprint: Optional[str] = None,
             expect_graph: Optional[str] = None) -> "EmbeddingStore":
        """Load a bundle file (or the unique/matching bundle in a directory).

        ``expect_fingerprint``/``expect_graph`` mismatches raise
        :class:`StaleServingArtifact` — a stale bundle is never served."""
        path = cls.resolve(path, expect_fingerprint)
        with np.load(path, allow_pickle=False) as z:
            data = {k: z[k] for k in z.files}
        meta = json.loads(str(data.pop("meta_json")))
        if meta.get("kind") != "serving" or \
                meta.get("version") != SERVING_VERSION:
            raise StaleServingArtifact(
                f"{path}: not a v{SERVING_VERSION} serving bundle "
                f"(meta={meta.get('kind')!r} v{meta.get('version')!r})")
        if expect_fingerprint is not None and \
                meta.get("partition_fingerprint") != expect_fingerprint:
            raise StaleServingArtifact(
                f"{path}: partition fingerprint "
                f"{meta.get('partition_fingerprint')!r} != expected "
                f"{expect_fingerprint!r} — re-export the bundle "
                f"(pipeline run --serving-dir) instead of serving stale "
                f"embeddings")
        if expect_graph is not None and meta.get("graph") != expect_graph:
            raise StaleServingArtifact(
                f"{path}: graph fingerprint mismatch — the bundle was "
                f"exported from a different graph")
        classifier = {k[len("clf_"):]: v for k, v in data.items()
                      if k.startswith("clf_")}
        return cls(meta, data["part_labels"], data["embeddings"],
                   data["predictions"], data["head_w"], data["head_b"],
                   classifier)

    @staticmethod
    def resolve(path: str, expect_fingerprint: Optional[str] = None) -> str:
        """Resolve a bundle path: a file is taken as-is; a directory picks
        the fingerprint-matching bundle (or the newest when no fingerprint
        is expected)."""
        if os.path.isdir(path):
            if expect_fingerprint:
                cand = os.path.join(path, f"serving-{expect_fingerprint}.npz")
                if not os.path.exists(cand):
                    raise StaleServingArtifact(
                        f"no serving bundle for fingerprint "
                        f"{expect_fingerprint!r} under {path} — export one "
                        f"with pipeline run --serving-dir")
                return cand
            bundles = sorted(
                (os.path.getmtime(os.path.join(path, f)),
                 os.path.join(path, f))
                for f in os.listdir(path)
                if f.startswith("serving-") and f.endswith(".npz"))
            if not bundles:
                raise FileNotFoundError(f"no serving bundles under {path}")
            return bundles[-1][1]
        return path

    # ----- queries --------------------------------------------------------
    @property
    def fingerprint(self) -> str:
        return self.meta.get("partition_fingerprint", "")

    def is_known(self, node_id: int) -> bool:
        return 0 <= node_id < self.n

    def shard(self, pid: int) -> ShardStore:
        return self.shards[pid]

    def lookup(self, node_ids: np.ndarray) -> np.ndarray:
        """Gather embeddings for known nodes, routed shard-by-shard."""
        ids = np.asarray(node_ids, dtype=np.int64)
        out = np.empty((ids.shape[0], self.embed_dim), dtype=np.float32)
        pids = self.partition_of[ids]
        for p in np.unique(pids):
            sel = pids == p
            out[sel] = self.shards[p].embeddings[self._local_row[ids[sel]]]
        return out

    def summary(self) -> str:
        rows = ", ".join(f"p{s.pid}:{s.num_nodes}" for s in self.shards)
        return (f"EmbeddingStore(n={self.n}, k={self.k}, "
                f"E={self.embed_dim}, C={self.num_classes}, "
                f"fp={self.fingerprint}, shards=[{rows}])")
