"""LRU hot-node cache in front of the embedding store (DESIGN.md §13).

A Zipf-shaped query stream concentrates on a small hot set; the cache keeps
those rows in front of the sharded store lookup and counts hits/misses so
the serving benchmark can report a real hit rate. Plain ``OrderedDict``
LRU — the store lookup it shadows is a numpy gather, so the cache's value
in-process is the counters and the contract, not wall time; in a multi-host
deployment the same object fronts a network fetch.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional

import numpy as np

from repro import obs

__all__ = ["LruNodeCache"]


class LruNodeCache:
    """Bounded node-id -> embedding-row LRU with hit/miss counters."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._d: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, node_id: int) -> bool:
        return int(node_id) in self._d

    def get(self, node_id: int) -> Optional[np.ndarray]:
        key = int(node_id)
        row = self._d.get(key)
        if row is None:
            self.misses += 1
            obs.counter("serving.cache.misses").inc()
            return None
        self._d.move_to_end(key)
        self.hits += 1
        obs.counter("serving.cache.hits").inc()
        return row

    def put(self, node_id: int, row: np.ndarray) -> None:
        key = int(node_id)
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = row
        if len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1
            obs.counter("serving.cache.evictions").inc()

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"capacity": self.capacity, "size": len(self._d),
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": round(self.hit_rate, 4)}
