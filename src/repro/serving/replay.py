"""Synthetic query-replay client + serving metrics (DESIGN.md §13).

Generates a Zipf-shaped workload over the store's node population (hot-set
concentration is what makes the LRU cache earn its hit rate), mixes in a
fraction of *unseen* node ids carrying neighbor lists (the inductive
fallback path, always including one zero-neighbor query so the degraded
path is exercised every run), drives the continuous batcher, and reduces
the answers to the ``BENCH_serving.json`` row schema:

    throughput_qps, p50_ms, p99_ms, cache_hit_rate,
    steady_state_recompiles, served/exact-match counters

Known-node answers are verified against the bundle's offline answer key
(``EmbeddingStore.predictions`` — the argmax of the trained classifier over
the pooled table, i.e. exactly what the offline ``PipelineReport``
evaluation predicts); ``verify=True`` hard-fails on any mismatch.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .batcher import Answer, ContinuousBatcher

__all__ = ["make_zipf_workload", "run_replay", "append_bench_rows",
           "DEFAULT_BENCH_JSON"]

DEFAULT_BENCH_JSON = os.path.join("benchmarks", "artifacts",
                                  "BENCH_serving.json")

Workload = List[Tuple[int, Optional[np.ndarray]]]


def make_zipf_workload(n: int, num_queries: int = 10_000,
                       alpha: float = 1.1, unseen_frac: float = 0.02,
                       max_neighbors: int = 32, seed: int = 0) -> Workload:
    """(node_id, neighbors) pairs; neighbors only for unseen ids >= n.

    Known queries draw node *ranks* from a Zipf(alpha) law mapped through a
    seed-fixed permutation (so the hot set is not just the low ids).
    Unseen queries get fresh ids ``n, n+1, ...`` and 1..max_neighbors known
    neighbors biased toward the same hot set; the FIRST unseen query has no
    neighbors at all — the degraded path is replayed every time."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    ranks = rng.zipf(alpha, size=num_queries * 2)
    ranks = ranks[ranks <= n][:num_queries] - 1
    while ranks.shape[0] < num_queries:    # top up the rejected tail
        extra = rng.zipf(alpha, size=num_queries)
        extra = extra[extra <= n] - 1
        ranks = np.concatenate([ranks, extra])[:num_queries]
    nodes = perm[ranks]

    workload: Workload = [(int(v), None) for v in nodes]
    n_unseen = int(round(num_queries * unseen_frac))
    if n_unseen:
        slots = rng.choice(num_queries, size=n_unseen, replace=False)
        for j, slot in enumerate(np.sort(slots)):
            if j == 0:
                nbs = np.zeros(0, dtype=np.int64)   # zero-known-neighbor
            else:
                d = int(rng.integers(1, max_neighbors + 1))
                nbs = perm[np.minimum(rng.zipf(alpha, size=d), n) - 1]
            workload[slot] = (n + j, nbs)
    return workload


def run_replay(batcher: ContinuousBatcher, workload: Workload,
               verify: bool = True) -> Dict[str, Any]:
    """Drive the batcher through the workload; returns the metrics row."""
    store = batcher.store
    warm_compiles = batcher.warmup()
    answers: List[Answer] = []
    t0 = time.perf_counter()
    for node_id, neighbors in workload:
        batcher.submit(node_id, neighbors=neighbors)
        answers.extend(batcher.pump())
    answers.extend(batcher.drain())
    wall = time.perf_counter() - t0

    assert len(answers) == len(workload), (len(answers), len(workload))
    lat = np.asarray([a.latency_ms for a in answers])
    by_source: Dict[str, int] = {}
    mismatches = []
    for a in answers:
        by_source[a.source] = by_source.get(a.source, 0) + 1
        if store.is_known(a.node_id) and \
                a.label != int(store.predictions[a.node_id]):
            mismatches.append((a.qid, a.node_id, a.label,
                               int(store.predictions[a.node_id])))
    if verify and mismatches:
        raise AssertionError(
            f"{len(mismatches)} served labels diverge from the offline "
            f"answer key (first: {mismatches[:3]}) — serving must match "
            f"the PipelineReport predictions exactly")

    stats = batcher.stats()
    return {
        "queries": len(workload),
        "wall_s": round(wall, 3),
        "throughput_qps": round(len(workload) / max(wall, 1e-9), 1),
        "p50_ms": round(float(np.percentile(lat, 50)), 3),
        "p99_ms": round(float(np.percentile(lat, 99)), 3),
        "mean_ms": round(float(lat.mean()), 3),
        "cache_hit_rate": stats["cache"]["hit_rate"],
        "warm_compiles": warm_compiles,
        "steady_state_recompiles": stats["steady_state_recompiles"],
        "flushes": stats["flushes"],
        "flush_reasons": stats["flush_reasons"],
        "served_by_source": by_source,
        "per_shard_served": stats["per_shard_served"],
        "label_mismatches": len(mismatches),
        "k": store.k,
        "n": store.n,
        "max_batch": batcher.max_batch,
        "max_wait_ms": batcher.max_wait_ms,
        "use_kernel": batcher.inductive.use_kernel,
        "partition_fingerprint": store.fingerprint,
    }


def append_bench_rows(rows: List[Dict[str, Any]],
                      path: str = DEFAULT_BENCH_JSON) -> str:
    """Append rows to the BENCH_serving.json trajectory.

    Uses ``benchmarks.common.append_bench_json`` when the benchmarks
    package is importable (normal repo-root invocation); otherwise falls
    back to an equivalent local atomic append so ``python -m repro.serving``
    works from anywhere."""
    try:
        from benchmarks.common import append_bench_json
        append_bench_json(path, rows)
        return path
    except ImportError:
        pass
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (OSError, ValueError):
            history = []
    stamp = time.time()
    history.extend({**r, "ts": stamp} for r in rows)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(history, f, indent=2)
    os.replace(tmp, path)
    return path
