"""Partition-sharded embedding serving (DESIGN.md §13).

The online half of the Leiden-Fusion story: the offline pipeline trains one
GNN per partition and pools a global embedding table; this package serves
that table — shard-routed lookups keyed by partition label, continuous
batching at fixed pow2 bucket shapes (zero steady-state recompiles), an LRU
hot-node cache, and an inductive fallback that aggregates a *new* node's
neighbors through the training aggregation kernel and answers with the
owning partition's head.

Entry points:

- ``python -m repro.serving`` — end-to-end Zipf replay (the acceptance path)
- ``python -m repro.serving serve`` / ``client`` — multi-process layout
- :func:`export_from_pipeline` — bundle export hook (called by the pipeline
  when ``PipelineConfig.serving_dir`` is set)
- :class:`EmbeddingStore` / :class:`ContinuousBatcher` — library use
"""
from .batcher import (Answer, CompileLog, ContinuousBatcher, Query,
                      bucket_of, bucket_sizes)
from .cache import LruNodeCache
from .inductive import InductiveEngine, route_neighbors
from .replay import (DEFAULT_BENCH_JSON, append_bench_rows,
                     make_zipf_workload, run_replay)
from .store import (SERVING_VERSION, EmbeddingStore, ShardStore,
                    StaleServingArtifact, export_from_pipeline,
                    export_serving_bundle)

__all__ = [
    "Answer", "CompileLog", "ContinuousBatcher", "Query",
    "bucket_of", "bucket_sizes",
    "LruNodeCache",
    "InductiveEngine", "route_neighbors",
    "DEFAULT_BENCH_JSON", "append_bench_rows", "make_zipf_workload",
    "run_replay",
    "SERVING_VERSION", "EmbeddingStore", "ShardStore",
    "StaleServingArtifact", "export_from_pipeline", "export_serving_bundle",
]
