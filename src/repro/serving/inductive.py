"""Inductive fallback: answer nodes the training run never saw.

A query for an unknown node id arrives with the ids of its (known)
neighbors. Instead of failing, the serving layer:

1. routes the query to the partition owning the *majority* of those
   neighbors (ties break to the smallest pid — deterministic);
2. aggregates the neighbors' stored embeddings on the fly through the SAME
   aggregation primitive the training path uses (`use_kernel=True` resolves
   the autotuned :class:`repro.kernels.autotune.KernelConfig` for the
   bucket's star-graph shape and threads it into the jit statically —
   Pallas strategies run the tuned-tile kernel, the XLA strategy the jnp
   segment-sum; bit-identical semantics, pinned by tests);
3. runs the owning partition's trained GNN head on the aggregate.

Shapes are fixed per flush bucket — ``[B_pad * (1 + max_neighbors)]`` rows,
one synthetic star graph per query — so the steady state never recompiles
(the same discipline as the known-node path, DESIGN.md §13).

A query with ZERO known neighbors degrades gracefully: the aggregate is the
zero vector, the answer is the head-bias argmax of shard 0 and is flagged
``degraded`` — never a crash.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["InductiveEngine", "route_neighbors"]


def route_neighbors(partition_of: np.ndarray,
                    neighbors: Optional[Sequence[int]]
                    ) -> Tuple[int, np.ndarray]:
    """(owning pid, known-neighbor ids) for an unseen node.

    Neighbors outside ``[0, n)`` are discarded (they are not in the store);
    with no known neighbor the pid is ``-1`` — the degraded path."""
    n = partition_of.shape[0]
    nb = np.asarray(neighbors if neighbors is not None else [],
                    dtype=np.int64).reshape(-1)
    nb = nb[(nb >= 0) & (nb < n)]
    if nb.size == 0:
        return -1, nb
    counts = np.bincount(partition_of[nb])
    return int(counts.argmax()), nb


@functools.partial(jax.jit, static_argnames=("max_neighbors", "use_kernel",
                                             "kernel_config"))
def _aggregate_and_head(nb_emb, nb_mask, head_w, head_b, *,
                        max_neighbors: int, use_kernel: bool,
                        kernel_config=None):
    """Fixed-shape batched star-graph aggregation + per-query head.

    nb_emb: [B, M, E] neighbor embeddings (zero rows where masked)
    nb_mask: [B, M] 1.0 for a real neighbor
    head_w: [B, E, C], head_b: [B, C] — the owning shard's head, gathered
    per query by the caller.

    Row layout of the synthetic graph: the first B rows are the query nodes
    (zero features), followed by the B*M neighbor rows; every arc points a
    neighbor row at its query row with the mask as weight, so
    ``aggregate_mean`` lands the masked neighbor mean exactly on rows
    ``[:B]`` on both the jnp and the Pallas path.

    ``kernel_config`` is the resolved autotuned
    :class:`repro.kernels.autotune.KernelConfig` for this bucket's star
    graph — static, so a retune recompiles instead of serving a stale
    kernel (DESIGN.md §14). ``None`` falls back to trace-time resolution
    inside ``aggregate_mean``.
    """
    b, m, e = nb_emb.shape
    assert m == max_neighbors, (m, max_neighbors)
    h = jnp.concatenate(
        [jnp.zeros((b, e), nb_emb.dtype), nb_emb.reshape(b * m, e)], axis=0)
    edge_src = b + jnp.arange(b * m, dtype=jnp.int32)
    edge_dst = jnp.repeat(jnp.arange(b, dtype=jnp.int32), m)
    weight = nb_mask.reshape(-1).astype(jnp.float32)
    counts = nb_mask.sum(axis=1)
    in_degree = jnp.concatenate(
        [counts, jnp.ones((b * m,), jnp.float32)], axis=0)
    if use_kernel and kernel_config is not None and \
            kernel_config.uses_pallas:
        from repro.kernels.ops import csr_aggregate
        inv = 1.0 / jnp.maximum(in_degree, 1.0)
        agg = csr_aggregate(h, edge_src, edge_dst, weight,
                            num_nodes=h.shape[0], inv_scale=inv,
                            config=kernel_config)[:b]
    else:
        from repro.gnn.layers import aggregate_mean
        agg = aggregate_mean(h, edge_src, edge_dst, weight, in_degree,
                             use_kernel=use_kernel
                             and kernel_config is None)[:b]
    logits = jnp.einsum("be,bec->bc", agg, head_w) + head_b
    return agg, logits


class InductiveEngine:
    """Batched on-the-fly aggregation for unseen nodes."""

    def __init__(self, store, max_neighbors: int = 32,
                 use_kernel: bool = False):
        self.store = store
        self.max_neighbors = int(max_neighbors)
        self.use_kernel = bool(use_kernel)

    def route(self, neighbors) -> Tuple[int, np.ndarray]:
        return route_neighbors(self.store.partition_of, neighbors)

    def kernel_config(self, b_pad: int):
        """Autotuned :class:`~repro.kernels.autotune.KernelConfig` for this
        bucket's star graph ([B·(1+M)] rows, [B·M] arcs, embed_dim wide) —
        what ``infer`` threads into the jit as a static arg. ``None`` on
        the jnp path."""
        if not self.use_kernel:
            return None
        from repro.kernels.autotune import get_config
        m = self.max_neighbors
        return get_config(b_pad * (1 + m), b_pad * m, self.store.embed_dim)

    def prepare(self, neighbor_lists: List[np.ndarray], b_pad: int
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Host-side gather into the fixed [b_pad, M, E] layout.

        Returns (nb_emb, nb_mask, pids). Neighbor lists longer than
        ``max_neighbors`` are truncated (deterministically, by position)."""
        m, e = self.max_neighbors, self.store.embed_dim
        nb_emb = np.zeros((b_pad, m, e), dtype=np.float32)
        nb_mask = np.zeros((b_pad, m), dtype=np.float32)
        pids = np.zeros(b_pad, dtype=np.int32)
        for i, nbs in enumerate(neighbor_lists):
            pid, known = route_neighbors(self.store.partition_of, nbs)
            known = known[:m]
            pids[i] = max(pid, 0)      # degraded queries compute on shard 0
            if known.size:
                nb_emb[i, :known.size] = self.store.lookup(known)
                nb_mask[i, :known.size] = 1.0
        return nb_emb, nb_mask, pids

    def infer(self, neighbor_lists: List[np.ndarray], b_pad: int
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(embeddings [b_pad, E], logits [b_pad, C], degraded [b_pad]).

        Only the first ``len(neighbor_lists)`` rows are real queries."""
        nb_emb, nb_mask, pids = self.prepare(neighbor_lists, b_pad)
        head_w = jnp.asarray(self.store.head_w)[pids]
        head_b = jnp.asarray(self.store.head_b)[pids]
        emb, logits = _aggregate_and_head(
            jnp.asarray(nb_emb), jnp.asarray(nb_mask), head_w, head_b,
            max_neighbors=self.max_neighbors, use_kernel=self.use_kernel,
            kernel_config=self.kernel_config(b_pad))
        degraded = nb_mask.sum(axis=1) == 0
        return np.asarray(emb), np.asarray(logits), degraded

    @property
    def jitted(self):
        """The underlying jitted callable (compile accounting hooks here)."""
        return _aggregate_and_head
