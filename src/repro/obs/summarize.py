"""Aggregate + validate repro-obs trace files.

``python -m repro.obs summarize out.json`` prints a per-span-name table
(count, total/mean/min/max wall ms, share of the root span) plus the
embedded metrics snapshot. ``python -m repro.obs validate out.json
--require dataset partition train classifier`` is the CI gate: it checks
the document parses as Chrome trace-event JSON with the repro schema
marker and that every required name matches at least one span
(``--require partition`` accepts a ``partition`` category, any
``partition.*`` span, or any ``*.partition`` span — so the mandatory
pipeline-stage set can be named without the ``pipeline.`` prefix).
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Sequence

__all__ = ["load_trace", "validate_trace", "summarize_trace",
           "format_summary"]


def load_trace(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)


def _complete_events(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    return [e for e in doc.get("traceEvents", []) if e.get("ph") == "X"]


def validate_trace(doc: Dict[str, Any],
                   require: Sequence[str] = ()) -> List[str]:
    """Return a list of problems (empty == valid)."""
    problems: List[str] = []
    if not isinstance(doc, dict):
        return ["trace document is not a JSON object"]
    if doc.get("schema") != "repro-obs-trace":
        problems.append("missing schema marker 'repro-obs-trace'")
    if not isinstance(doc.get("version"), int):
        problems.append("missing integer 'version'")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        problems.append("'traceEvents' missing or empty")
        return problems
    for i, e in enumerate(events):
        if not isinstance(e, dict) or "ph" not in e:
            problems.append(f"event {i} is not a trace event (no 'ph')")
            continue
        if e["ph"] == "X":
            for field in ("name", "ts", "dur", "pid", "tid"):
                if field not in e:
                    problems.append(f"event {i} ({e.get('name')!r}) "
                                    f"missing {field!r}")
            if isinstance(e.get("dur"), (int, float)) and e["dur"] < 0:
                problems.append(f"event {i} has negative dur")
    complete = _complete_events(doc)
    if not complete:
        problems.append("no complete (ph='X') span events")
    names = {e.get("name", "") for e in complete}
    cats = {e.get("cat", "") for e in complete}
    for req in require:
        if req in names or req in cats or any(
                n.startswith(req + ".") or n.endswith("." + req)
                for n in names):
            continue
        problems.append(f"required span {req!r} not present")
    return problems


def summarize_trace(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Per-span-name aggregates, sorted by total time descending."""
    agg: Dict[str, Dict[str, Any]] = {}
    total_wall_us = 0.0
    for e in _complete_events(doc):
        dur = float(e.get("dur", 0.0))
        row = agg.setdefault(e["name"], {
            "name": e["name"], "count": 0, "total_us": 0.0,
            "min_us": None, "max_us": 0.0})
        row["count"] += 1
        row["total_us"] += dur
        row["max_us"] = max(row["max_us"], dur)
        row["min_us"] = dur if row["min_us"] is None else min(
            row["min_us"], dur)
        # Depth-0 spans partition wall time; their sum is the run's wall.
        if e.get("args", {}).get("depth") == 0:
            total_wall_us += dur
    rows = sorted(agg.values(), key=lambda r: -r["total_us"])
    for r in rows:
        r["mean_us"] = r["total_us"] / r["count"]
        r["share"] = (r["total_us"] / total_wall_us) if total_wall_us else 0.0
    return rows


def format_summary(doc: Dict[str, Any], top: int = 0) -> str:
    rows = summarize_trace(doc)
    if top:
        rows = rows[:top]
    lines = [f"{'span':<34s} {'count':>7s} {'total ms':>10s} "
             f"{'mean ms':>9s} {'min ms':>9s} {'max ms':>9s} {'share':>6s}"]
    lines.append("-" * len(lines[0]))
    for r in rows:
        lines.append(
            f"{r['name']:<34s} {r['count']:>7d} "
            f"{r['total_us'] / 1000:>10.2f} {r['mean_us'] / 1000:>9.3f} "
            f"{r['min_us'] / 1000:>9.3f} {r['max_us'] / 1000:>9.3f} "
            f"{r['share'] * 100:>5.1f}%")
    metrics = doc.get("metrics") or {}
    if metrics:
        lines.append("")
        lines.append(f"{'metric':<44s} {'kind':<10s} value")
        lines.append("-" * 72)
        for name, m in metrics.items():
            val = m.get("value")
            if isinstance(val, dict):   # histogram: compact one-liner
                val = (f"count={val.get('count')} sum={val.get('sum'):.6g} "
                       f"min={val.get('min')} max={val.get('max')}")
            lines.append(f"{name:<44s} {m.get('kind', ''):<10s} {val}")
    if "droppedEvents" in doc:
        lines.append("")
        lines.append(f"warning: {doc['droppedEvents']} events dropped "
                     f"(trace buffer cap)")
    return "\n".join(lines)
