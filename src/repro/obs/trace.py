"""Nestable spans with Chrome trace-event / Perfetto JSON export.

A span is a timed region: ``with tracer.span("partition.local_move",
level=0, arcs=n):``. Spans nest via a thread-local stack, record wall
time (``time.perf_counter`` deltas against the tracer's start), thread
id, and arbitrary JSON-able attributes, and survive exceptions — the
context manager always closes the span and stamps an ``error`` attribute
with the exception type on the way out.

Export targets the Chrome trace-event format (the ``chrome://tracing`` /
Perfetto "JSON Object Format"): a top-level object whose ``traceEvents``
list holds complete events (``ph: "X"``) with microsecond ``ts``/``dur``.
Extra top-level keys are explicitly allowed by that format, so the export
carries the repro schema marker and a metrics-registry snapshot alongside
the events (DESIGN.md §16).

This module is stdlib-only on purpose: ``repro.core.graph`` and
``repro.core.engine`` import ``repro.obs`` at module level, so anything
heavier here would create an import cycle (and slow every cold start).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = ["Span", "Tracer", "MAX_EVENTS"]

# Memory bound: a span record is ~200 bytes, so the cap holds the trace
# buffer under ~100MB even if a caller instruments a per-arc loop.
MAX_EVENTS = 500_000


class Span:
    """One open (then closed) timed region."""

    __slots__ = ("name", "attrs", "t0", "duration", "tid", "depth")

    def __init__(self, name: str, attrs: Dict[str, Any], t0: float,
                 tid: int, depth: int):
        self.name = name
        self.attrs = attrs
        self.t0 = t0
        self.duration: Optional[float] = None   # seconds, set on close
        self.tid = tid
        self.depth = depth

    def set(self, **attrs: Any) -> None:
        """Attach/overwrite attributes on an open span."""
        self.attrs.update(attrs)


class _SpanContext:
    """Context manager binding a Span to the tracer's thread-local stack."""

    __slots__ = ("_tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self.span = span

    def __enter__(self) -> Span:
        return self.span

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self.span.attrs["error"] = exc_type.__name__
        self._tracer._close(self.span)
        return False   # never swallow the exception


class Tracer:
    """Collects spans process-wide; thread-safe, one stack per thread."""

    def __init__(self):
        self._lock = threading.Lock()
        self._events: List[Span] = []
        self._local = threading.local()
        self._epoch = time.perf_counter()
        self._dropped = 0

    # -- recording ---------------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **attrs: Any) -> _SpanContext:
        stack = self._stack()
        sp = Span(name, attrs, time.perf_counter(),
                  threading.get_ident(), len(stack))
        stack.append(sp)
        return _SpanContext(self, sp)

    def _close(self, sp: Span) -> None:
        sp.duration = time.perf_counter() - sp.t0
        stack = self._stack()
        # Exception safety: unwind past any inner spans a non-local exit
        # (e.g. generator close) left open, closing them with this one.
        while stack:
            inner = stack.pop()
            if inner is sp:
                break
            if inner.duration is None:
                inner.duration = time.perf_counter() - inner.t0
                self._record(inner)
        self._record(sp)

    def _record(self, sp: Span) -> None:
        with self._lock:
            if len(self._events) >= MAX_EVENTS:
                self._dropped += 1
                return
            self._events.append(sp)

    # -- introspection -----------------------------------------------------

    def event_count(self) -> int:
        with self._lock:
            return len(self._events)

    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._events)

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
            self._dropped = 0
            self._epoch = time.perf_counter()

    # -- export ------------------------------------------------------------

    def to_chrome(self, metrics: Optional[Dict[str, Any]] = None,
                  schema_version: int = 1) -> Dict[str, Any]:
        """Build the Chrome trace-event JSON object."""
        pid = os.getpid()
        events: List[Dict[str, Any]] = [{
            "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
            "args": {"name": "repro"},
        }]
        with self._lock:
            spans = list(self._events)
            dropped = self._dropped
        for sp in spans:
            cat = sp.name.split(".", 1)[0]
            args = dict(sp.attrs)
            args["depth"] = sp.depth
            events.append({
                "ph": "X",
                "name": sp.name,
                "cat": cat,
                "pid": pid,
                "tid": sp.tid,
                "ts": round((sp.t0 - self._epoch) * 1e6, 3),
                "dur": round((sp.duration or 0.0) * 1e6, 3),
                "args": args,
            })
        out: Dict[str, Any] = {
            "schema": "repro-obs-trace",
            "version": schema_version,
            "displayTimeUnit": "ms",
            "traceEvents": events,
        }
        if dropped:
            out["droppedEvents"] = dropped
        if metrics is not None:
            out["metrics"] = metrics
        return out

    def export(self, path: str, metrics: Optional[Dict[str, Any]] = None,
               schema_version: int = 1) -> str:
        doc = self.to_chrome(metrics=metrics, schema_version=schema_version)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, path)
        return path
