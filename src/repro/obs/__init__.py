"""repro.obs — unified tracing + metrics for the whole stack.

Usage (DESIGN.md §16)::

    from repro import obs

    with obs.span("partition.local_move", level=lvl, arcs=int(n_arcs)):
        ...                                   # timed + attributed region

    obs.counter("graphstore.chunks").inc()    # always-on metrics
    obs.gauge("train.loss.p0").set(0.31)
    obs.histogram("serving.batch_size").record(24)

Tracing is **disabled by default**. ``obs.span(...)`` in disabled mode
returns a shared no-op context manager — no allocation, no lock, no
timestamp — so instrumented hot loops cost one function call and one
attribute check (<1% of pipeline wall, gated by
``tools/obs_overhead_smoke.py``). Call sites that would compute expensive
attributes to feed a span (e.g. ``float(loss)``, which forces a JAX
device sync) must guard on :func:`enabled` first.

Metrics are **always live** — a counter increment is one locked integer
add — so subsystems use registry counters as primary storage (serving's
cache/compile books) and snapshots stay deterministic across processes.

``obs.enable()`` turns span collection on; ``obs.export_trace(path)``
writes Chrome trace-event JSON (open in Perfetto / ``chrome://tracing``);
``python -m repro.obs summarize out.json`` aggregates it per span name.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .sampler import jax_profiler_session, peak_rss_bytes, sample_memory
from .trace import Span, Tracer

__all__ = [
    "SCHEMA_VERSION", "enabled", "enable", "disable", "span", "counter",
    "gauge", "histogram", "registry", "tracer", "export_trace",
    "trace_document", "sample_memory_now", "profiler_session", "reset",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Span", "Tracer",
    "peak_rss_bytes",
]

# Bumped when the exported trace document's shape changes; stamped into
# traces and benchmark rows so trajectories stay attributable.
SCHEMA_VERSION = 1

_enabled = False
_tracer = Tracer()
_registry = MetricsRegistry()


class _NoopSpan:
    """Shared do-nothing span for the disabled fast path."""

    __slots__ = ()
    duration: Optional[float] = None

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def set(self, **attrs: Any) -> None:
        pass


_NOOP_SPAN = _NoopSpan()


def enabled() -> bool:
    """Whether span collection is on (metrics are always on)."""
    return _enabled


def enable() -> None:
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def span(name: str, **attrs: Any):
    """Open a nested span; no-op (shared singleton) when disabled."""
    if not _enabled:
        return _NOOP_SPAN
    return _tracer.span(name, **attrs)


def counter(name: str) -> Counter:
    return _registry.counter(name)


def gauge(name: str) -> Gauge:
    return _registry.gauge(name)


def histogram(name: str) -> Histogram:
    return _registry.histogram(name)


def registry() -> MetricsRegistry:
    return _registry


def tracer() -> Tracer:
    return _tracer


def trace_document() -> Dict[str, Any]:
    """The Chrome trace-event JSON object for everything recorded so far."""
    return _tracer.to_chrome(metrics=_registry.snapshot(),
                             schema_version=SCHEMA_VERSION)


def export_trace(path: str) -> str:
    """Write the trace (+ metrics snapshot) to ``path``; returns ``path``."""
    return _tracer.export(path, metrics=_registry.snapshot(),
                          schema_version=SCHEMA_VERSION)


def sample_memory_now() -> None:
    """Sample peak RSS / JAX device memory into the registry gauges."""
    sample_memory(_registry)


def profiler_session(out_dir: Optional[str]) -> jax_profiler_session:
    """``jax.profiler`` hook for the training stage (no-op if dir is None)."""
    return jax_profiler_session(out_dir, registry=_registry)


def reset() -> None:
    """Clear spans and metrics and disable tracing (test isolation)."""
    global _enabled
    _enabled = False
    _tracer.reset()
    _registry.reset()
