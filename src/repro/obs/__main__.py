"""CLI for trace files.

    PYTHONPATH=src python -m repro.obs summarize out.json
    PYTHONPATH=src python -m repro.obs validate out.json \
        --require dataset partition train classifier
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from .summarize import format_summary, load_trace, validate_trace


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize / validate repro-obs Chrome trace files.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("summarize", help="per-span aggregate table")
    s.add_argument("trace", help="trace JSON emitted via --trace")
    s.add_argument("--top", type=int, default=0,
                   help="only show the N hottest span names")

    v = sub.add_parser("validate", help="schema + required-span check")
    v.add_argument("trace")
    v.add_argument("--require", nargs="*", default=[],
                   help="span names/categories that must be present "
                        "(prefix match on 'name.'), e.g. dataset partition")

    args = ap.parse_args(argv)
    doc = load_trace(args.trace)
    if args.cmd == "summarize":
        print(format_summary(doc, top=args.top))
        return 0
    problems = validate_trace(doc, require=args.require)
    if problems:
        for p in problems:
            print(f"INVALID: {p}", file=sys.stderr)
        return 1
    n = sum(1 for e in doc["traceEvents"] if e.get("ph") == "X")
    print(f"OK: {args.trace} valid repro-obs trace "
          f"(version {doc.get('version')}, {n} spans)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
