"""Memory samplers and the optional ``jax.profiler`` session hook.

Two memory sources feed gauges in the registry:

* host peak RSS — ``resource.getrusage(RUSAGE_SELF).ru_maxrss`` (kilobytes
  on Linux, bytes on macOS; normalized to bytes here). This is the number
  PR 9's out-of-core work gates on, so the pipeline samples it after every
  stage into ``process.peak_rss_bytes``.
* JAX device memory — ``device.memory_stats()`` where the backend exposes
  it (TPU/GPU do; CPU returns None). Sampled into
  ``jax.device.bytes_in_use`` / ``jax.device.peak_bytes_in_use``.

Everything JAX-touching imports lazily and fails soft: ``repro.obs`` must
stay importable (and fast) in processes that never load JAX, e.g. the
``summarize`` CLI reading a trace file.
"""
from __future__ import annotations

import sys
from typing import Optional

__all__ = ["peak_rss_bytes", "sample_memory", "jax_profiler_session"]


def peak_rss_bytes() -> Optional[int]:
    """Process peak RSS in bytes, or None where unsupported."""
    try:
        import resource
    except ImportError:          # non-POSIX
        return None
    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform == "darwin":
        return int(ru)           # macOS reports bytes
    return int(ru) * 1024        # Linux reports kilobytes


def _device_memory() -> Optional[dict]:
    try:
        import jax
        stats = jax.devices()[0].memory_stats()
    except Exception:
        return None
    return stats or None


def sample_memory(registry) -> None:
    """Record current memory readings into ``registry`` gauges."""
    rss = peak_rss_bytes()
    if rss is not None:
        registry.gauge("process.peak_rss_bytes").set(rss)
    stats = _device_memory()
    if stats:
        for key in ("bytes_in_use", "peak_bytes_in_use"):
            if key in stats:
                registry.gauge(f"jax.device.{key}").set(stats[key])


class jax_profiler_session:
    """Context manager starting a ``jax.profiler`` trace for its body.

    Used around the training stage when the pipeline is given a profile
    directory (``--jax-profile DIR``). Fails soft: if the profiler can't
    start (backend without support, double-start), the body still runs and
    the failure is recorded as a ``jax.profiler.failed`` counter.
    """

    def __init__(self, out_dir: Optional[str], registry=None):
        self.out_dir = out_dir
        self._registry = registry
        self._active = False

    def __enter__(self):
        if not self.out_dir:
            return self
        try:
            import jax
            jax.profiler.start_trace(self.out_dir)
            self._active = True
        except Exception:
            if self._registry is not None:
                self._registry.counter("jax.profiler.failed").inc()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._active:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception:
                if self._registry is not None:
                    self._registry.counter("jax.profiler.failed").inc()
        return False
