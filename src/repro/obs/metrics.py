"""Process-wide metrics registry: counters, gauges, pow2 histograms.

The registry replaces the scattered ad-hoc counters that grew per-subsystem
(serving's LRU hit/miss fields, ``CompileLog`` compile books, the engine's
implicit sweep counts) with one namespace (DESIGN.md §16). Unlike spans —
which are gated behind :func:`repro.obs.enabled` — metrics are *always
live*: a metric mutation is one locked integer/float update, cheap enough
that subsystems can use registry-backed counters as their primary storage
(the serving cache does) without an enable/disable mode changing what they
report. Determinism matters: two processes running the same workload must
produce identical counter snapshots (pinned by ``tests/test_obs.py``), so
nothing here records wall-clock state — time lives in spans and gauges.

Histogram buckets are fixed powers of two: value ``v`` lands in the bucket
whose upper bound is the smallest ``2**i >= v`` (``v <= 1`` lands in the
``le=1`` bucket, everything past ``2**62`` in the overflow bucket). Fixed
buckets make histograms mergeable across processes and snapshots comparable
across runs — the same reason the serving batcher flushes at pow2 batch
shapes.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "pow2_bucket_index"]

_MAX_BUCKET_EXP = 62   # buckets le=2^0 .. le=2^62, plus one overflow slot


def pow2_bucket_index(value: float) -> int:
    """Index of the pow2 bucket ``value`` falls in (0 => le=1)."""
    if value <= 1:
        return 0
    v = int(value) if value == int(value) else int(value) + 1
    idx = (v - 1).bit_length()
    return min(idx, _MAX_BUCKET_EXP + 1)


class Counter:
    """Monotonic counter."""

    kind = "counter"

    def __init__(self, registry: "MetricsRegistry", name: str):
        self._registry = registry
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._registry._lock:
            self.value += n
            self._registry._ops += 1

    def snapshot(self):
        return self.value


class Gauge:
    """Last-write-wins value (may be float; not part of deterministic
    snapshots — gauges typically carry sampled state like RSS)."""

    kind = "gauge"

    def __init__(self, registry: "MetricsRegistry", name: str):
        self._registry = registry
        self.name = name
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        with self._registry._lock:
            self.value = float(v)
            self._registry._ops += 1

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed pow2-bucket histogram with count/sum/min/max."""

    kind = "histogram"

    def __init__(self, registry: "MetricsRegistry", name: str):
        self._registry = registry
        self.name = name
        self.counts: List[int] = [0] * (_MAX_BUCKET_EXP + 2)
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def record(self, value: float) -> None:
        value = float(value)
        with self._registry._lock:
            self.counts[pow2_bucket_index(value)] += 1
            self.count += 1
            self.sum += value
            self.min = value if self.min is None else min(self.min, value)
            self.max = value if self.max is None else max(self.max, value)
            self._registry._ops += 1

    def snapshot(self):
        # sparse bucket map: {"le=2^i": count} for non-empty buckets only
        buckets = {}
        for i, c in enumerate(self.counts):
            if c:
                key = f"le=2^{i}" if i <= _MAX_BUCKET_EXP else "overflow"
                buckets[key] = c
        return {"count": self.count, "sum": self.sum, "min": self.min,
                "max": self.max, "buckets": buckets}


class MetricsRegistry:
    """Create-or-get registry of named metrics (thread-safe)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}
        self._ops = 0     # total mutations — the overhead gate's event count

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(self, name)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def total_ops(self) -> int:
        """Total metric mutations so far (the disabled-overhead gate
        multiplies this by the measured per-op cost)."""
        return self._ops

    def snapshot(self, kinds: Optional[tuple] = None) -> Dict[str, object]:
        """{name: value} for every registered metric, sorted by name.

        ``kinds`` filters by metric kind (e.g. ``("counter",)`` gives the
        deterministic subset the two-process test compares).
        """
        with self._lock:
            items = sorted(self._metrics.items())
        out = {}
        for name, m in items:
            if kinds is not None and m.kind not in kinds:
                continue
            out[name] = {"kind": m.kind, "value": m.snapshot()}
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()
            self._ops = 0
