"""Quickstart: Leiden-Fusion in 30 seconds.

Partitions the Zachary karate club, prints the paper's quality metrics, then
runs the full pipeline (partition -> communication-free local training ->
embedding assembly -> classifier) through `repro.pipeline` — the same code
path as `python -m repro.pipeline run`.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.core import PartitionerSpec, evaluate_partition, karate_club, \
    leiden_fusion, make_arxiv_like, partition_from_spec
from repro.pipeline import Pipeline, PipelineConfig


def main():
    # --- 1. the paper's Figure 2: karate club, k=2 -------------------------
    g = karate_club()
    rep = evaluate_partition(g, leiden_fusion(g, k=2))
    print("karate k=2:", rep.as_dict())
    assert rep.max_components == 1 and rep.total_isolated == 0

    # --- 2. a real(ish) graph, via partitioner spec strings ----------------
    # any registered method, configured inline; "+f" composes fusion over
    # any base (run `python -m repro.pipeline partitioners` for the list)
    ds = make_arxiv_like(n=3000, feature_dim=64, seed=0)
    for spec in ("leiden_fusion", "metis", "metis+f(alpha=0.1)"):
        caps = PartitionerSpec.parse(spec).capabilities
        res = partition_from_spec(ds.graph, spec, 8, seed=0)
        rep = evaluate_partition(ds.graph, res.labels)
        print(f"{res.spec:20s} k=8: cut={rep.edge_cut_pct:5.1f}% "
              f"components={rep.total_components:3d} "
              f"isolated={rep.total_isolated} "
              f"[{caps.describe()}] fp={res.fingerprint}")

    # --- 3. the full pipeline, with the partition artifact cached ----------
    with tempfile.TemporaryDirectory() as cache:
        cfg = PipelineConfig(dataset="arxiv-like",
                             dataset_kwargs={"n": 3000, "feature_dim": 64},
                             method="leiden_fusion", k=4, scheme="repli",
                             mode="local", model="gcn", hidden_dim=64,
                             embed_dim=64, epochs=30, lr=5e-3,
                             classifier_epochs=100, cache_dir=cache)
        report = Pipeline(cfg).run(ds)
        print(report.summary())
        assert report.collectives["total"] == 0   # zero communication
        # second run: the partition artifact is loaded, not recomputed
        report2 = Pipeline(cfg).run(ds)
        assert report2.partition_cache_hit
        print("second run: partition served from cache "
              f"(test acc {report2.accuracy['test']:.3f})")


if __name__ == "__main__":
    main()
