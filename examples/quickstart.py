"""Quickstart: Leiden-Fusion in 30 seconds.

Partitions the Zachary karate club and a synthetic citation graph, prints
the paper's quality metrics, then runs the full local-training pipeline on a
small graph.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (build_partition_batch, evaluate_partition,
                        karate_club, leiden_fusion, make_arxiv_like,
                        metis_partition)
from repro.gnn import GNNConfig, train_classifier, train_local


def main():
    # --- 1. the paper's Figure 2: karate club, k=2 -------------------------
    g = karate_club()
    labels = leiden_fusion(g, k=2)
    rep = evaluate_partition(g, labels)
    print("karate k=2:", rep.as_dict())
    assert rep.max_components == 1 and rep.total_isolated == 0

    # --- 2. a real(ish) graph: LF vs METIS quality -------------------------
    ds = make_arxiv_like(n=3000, feature_dim=64, seed=0)
    for name, fn in (("leiden_fusion", leiden_fusion),
                     ("metis", metis_partition)):
        rep = evaluate_partition(ds.graph, fn(ds.graph, 8))
        print(f"{name:14s} k=8: cut={rep.edge_cut_pct:5.1f}% "
              f"components={rep.total_components:3d} "
              f"isolated={rep.total_isolated}")

    # --- 3. the paper's pipeline: partition -> local GNNs -> classifier ----
    labels = leiden_fusion(ds.graph, 4)
    batch = build_partition_batch(ds.graph, labels, scheme="repli")
    cfg = GNNConfig(kind="gcn", feature_dim=64, hidden_dim=64, embed_dim=64,
                    num_layers=3, dropout=0.3)
    _, embeddings = train_local(ds, batch, cfg, epochs=30, lr=5e-3)
    res = train_classifier(ds, embeddings, epochs=100)
    print(f"LF k=4 Repli: test accuracy {res['test']:.3f} "
          f"(trained with ZERO inter-partition communication)")


if __name__ == "__main__":
    main()
