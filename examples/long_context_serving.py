"""Long-context serving with recurrent state (the long_500k shape, scaled to
CPU): an xLSTM decodes with O(1) state after consuming a long prompt, and a
sliding-window dense model serves from a ring-buffer cache.

    PYTHONPATH=src python examples/long_context_serving.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_model, serve_step
from repro.models.lm import grow_cache, prefill_step


def run_arch(name, cfg, prompt_len=512, new_tokens=32):
    rng = np.random.default_rng(0)
    params = init_model(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, prompt_len)),
                         jnp.int32)
    prefill = jax.jit(lambda p, b: prefill_step(p, cfg, b))
    decode = jax.jit(lambda p, t, c, l: serve_step(p, cfg, t, c, l))
    t0 = time.time()
    logits, cache, lengths = prefill(params, {"tokens": tokens})
    cache = grow_cache(cache, prompt_len + new_tokens)
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(new_tokens):
        logits, cache = decode(params, nxt, cache, lengths)
        lengths = lengths + 1
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    cache_bytes = sum(x.nbytes for x in jax.tree.leaves(cache))
    print(f"{name:28s} prompt={prompt_len} +{new_tokens} tok: "
          f"{dt:5.1f}s  cache={cache_bytes/1e6:7.1f}MB  finite="
          f"{bool(jnp.isfinite(logits).all())}")


def main():
    # xLSTM: state is O(1) in sequence length
    run_arch("xlstm-125m (reduced)", get_config("xlstm_125m").reduced())
    # zamba2 hybrid: mamba states + shared-attn ring buffer
    run_arch("zamba2-1.2b (reduced)", get_config("zamba2_1p2b").reduced())
    # dense arch with sliding-window: ring buffer caps the cache
    cfg = dataclasses.replace(get_config("qwen3_4b").reduced(),
                              attention="sliding", window=128)
    run_arch("qwen3-4b (reduced, sw128)", cfg)


if __name__ == "__main__":
    main()
