"""Beyond-paper: Leiden-Fusion placement of MoE experts (DESIGN.md §4).

Simulates a realistic skewed router (experts co-activate in topic clusters),
builds the co-activation graph, and compares LF placement against the naive
contiguous split on the all-to-all dispersion metric.

    PYTHONPATH=src python examples/moe_expert_placement.py
"""
import numpy as np

from repro.core.expert_placement import (coactivation_graph,
                                         contiguous_placement,
                                         lf_expert_placement, placement_cost)


def synthetic_router_trace(num_experts=60, top_k=4, tokens=20000,
                           num_topics=12, seed=0):
    """Tokens belong to latent topics; each topic prefers a small expert
    subset (how real MoE routers behave after training)."""
    rng = np.random.default_rng(seed)
    topic_experts = [rng.choice(num_experts, size=8, replace=False)
                     for _ in range(num_topics)]
    out = np.zeros((tokens, top_k), dtype=np.int64)
    for t in range(tokens):
        topic = rng.integers(num_topics)
        prefer = topic_experts[topic]
        # 80% from the topic's preferred experts, 20% uniform
        choices = []
        while len(choices) < top_k:
            e = (rng.choice(prefer) if rng.random() < 0.8
                 else rng.integers(num_experts))
            if e not in choices:
                choices.append(int(e))
        out[t] = choices
    return out


def real_router_trace(tokens_per_topic=48, num_topics=24, steps=25, seed=0):
    """Extract a REAL router trace: train a reduced qwen2-moe briefly on
    topic-clustered synthetic text (token-id bands = topics), then record
    its top-k expert choices. Training specializes experts to topics, which
    creates the co-activation structure LF exploits."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import init_model
    from repro.models.lm import model_hidden_train, train_loss
    from repro.models.moe import _padded_e
    from repro.optim import adamw_init, adamw_update

    cfg = get_config("qwen2_moe_a2p7b").reduced(num_experts=16)
    rng = np.random.default_rng(seed)
    # topic-banded corpus: each sequence samples tokens from one band
    bands = np.array_split(np.arange(16, cfg.vocab_size), num_topics)
    seqs = []
    for t in range(num_topics):
        for _ in range(2):
            seqs.append(rng.choice(bands[t], size=tokens_per_topic))
    tokens = jnp.asarray(np.stack(seqs), jnp.int32)
    batch = {"tokens": tokens, "loss_mask": jnp.ones(tokens.shape,
                                                     jnp.float32)}
    params = init_model(jax.random.PRNGKey(seed), cfg)
    opt = adamw_init(params)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(lambda p: train_loss(p, cfg, b))(p)
        p, o = adamw_update(g, o, p, 3e-3)
        return p, o, loss

    for _ in range(steps):
        params, opt, _ = step(params, opt, batch)

    # record the trained router's top-k choices at layer 0
    from repro.models.layers import apply_norm
    x = params["embed"][tokens]
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    h = apply_norm(lp["ln2"], x)
    logits = h.astype(jnp.float32) @ lp["ffn"]["router"]
    _, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    return np.asarray(idx).reshape(-1, cfg.top_k), cfg.num_experts


def main():
    print("== synthetic clustered router (qwen2-moe geometry, 60e/4 shards)")
    num_experts, shards = 60, 4    # qwen2-moe-a2.7b geometry, 4-way EP group
    trace = synthetic_router_trace(num_experts)
    naive = contiguous_placement(num_experts, shards)
    lf = lf_expert_placement(trace, num_experts, shards)

    for name, placement in (("contiguous", naive), ("leiden_fusion", lf)):
        cost = placement_cost(trace, placement)
        print(f"{name:14s}: mean shards/token="
              f"{cost['mean_shards_per_token']:.3f}  "
              f"single-shard tokens={cost['single_shard_frac']*100:.1f}%  "
              f"p90={cost['p90_shards_per_token']:.0f}")
    c_naive = placement_cost(trace, naive)["mean_shards_per_token"]
    c_lf = placement_cost(trace, lf)["mean_shards_per_token"]
    print(f"all-to-all partner reduction: "
          f"{(1 - (c_lf - 1) / max(c_naive - 1, 1e-9)) * 100:.1f}% "
          f"fewer cross-shard hops")

    print("\n== REAL router trace (reduced qwen2-moe trained on topic-"
          "clustered text, 16e/4 shards)")
    trace, e = real_router_trace()
    naive = contiguous_placement(e, 4)
    lf = lf_expert_placement(trace, e, 4)
    for name, placement in (("contiguous", naive), ("leiden_fusion", lf)):
        cost = placement_cost(trace, placement)
        print(f"{name:14s}: mean shards/token="
              f"{cost['mean_shards_per_token']:.3f}  "
              f"single-shard tokens={cost['single_shard_frac']*100:.1f}%")


if __name__ == "__main__":
    main()
