"""End-to-end driver (paper pipeline at benchmark scale).

Reproduces the paper's core experiment through `repro.pipeline`: the same
GNN trained on partitions from different partitioning methods, Inner vs
Repli, versus the centralized reference — showing LF preserves accuracy
while training fully locally. Partitions are cached, so the two schemes
(and any rerun) reuse each method's partitioning.

    PYTHONPATH=src python examples/distributed_gnn_training.py --k 8
"""
import argparse
import os

from repro.core import make_arxiv_like
from repro.pipeline import Pipeline, PipelineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=8000)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=50)
    ap.add_argument("--model", choices=["gcn", "sage"], default="gcn")
    ap.add_argument("--cache-dir", default=None,
                    help="partition cache (default: ~/.cache/repro/examples)")
    args = ap.parse_args()

    cache = args.cache_dir or os.path.expanduser(
        os.path.join("~", ".cache", "repro", "examples"))
    ds = make_arxiv_like(n=args.nodes)

    def run(method, k, scheme):
        cfg = PipelineConfig(method=method, k=k, scheme=scheme,
                             mode="local", model=args.model, hidden_dim=128,
                             embed_dim=128, num_layers=3, dropout=0.3,
                             epochs=args.epochs, lr=5e-3,
                             classifier_epochs=120, cache_dir=cache,
                             collect_hlo=False)
        return Pipeline(cfg).run(ds)

    ref = run("single", 1, "inner")
    print(f"centralized: test={ref.accuracy['test']:.3f}")

    # methods are partitioner spec strings — "lpa+f(alpha=0.1)" is the
    # paper's +F operator over LPA, cached under its own config fingerprint
    for method in ("leiden_fusion", "metis", "lpa", "lpa+f(alpha=0.1)",
                   "random"):
        for scheme in ("inner", "repli"):
            rep = run(method, args.k, scheme)
            p = rep.partition
            cached = "cached" if rep.partition_cache_hit else "fresh "
            print(f"{method:18s} k={args.k} {scheme:5s}: "
                  f"test={rep.accuracy['test']:.3f} "
                  f"(cut={p['edge_cut_pct']:.1f}% "
                  f"comps={p['total_components']} "
                  f"iso={p['total_isolated']}, partition {cached}, "
                  f"train {rep.timings['train']:.0f}s)")


if __name__ == "__main__":
    main()
