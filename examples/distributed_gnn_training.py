"""End-to-end driver (paper pipeline at benchmark scale).

Reproduces the paper's core experiment: the same GNN trained on partitions
from different partitioning methods, Inner vs Repli, versus the centralized
reference — showing LF preserves accuracy while training fully locally.

    PYTHONPATH=src python examples/distributed_gnn_training.py --k 8
"""
import argparse
import time

import numpy as np

from repro.core import (PARTITIONERS, build_partition_batch,
                        evaluate_partition, make_arxiv_like)
from repro.gnn import GNNConfig, train_classifier, train_local


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=8000)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=50)
    ap.add_argument("--model", choices=["gcn", "sage"], default="gcn")
    args = ap.parse_args()

    ds = make_arxiv_like(n=args.nodes)
    cfg = GNNConfig(kind=args.model, feature_dim=ds.features.shape[1],
                    hidden_dim=128, embed_dim=128, num_layers=3, dropout=0.3)

    # centralized reference (k=1)
    ref_batch = build_partition_batch(
        ds.graph, np.zeros(ds.graph.n, dtype=np.int64), scheme="inner")
    _, ref_emb = train_local(ds, ref_batch, cfg, epochs=args.epochs, lr=5e-3)
    ref = train_classifier(ds, ref_emb, epochs=120)
    print(f"centralized: test={ref['test']:.3f}")

    for method in ("leiden_fusion", "metis", "lpa", "random"):
        labels = PARTITIONERS[method](ds.graph, args.k, seed=0)
        rep = evaluate_partition(ds.graph, labels)
        for scheme in ("inner", "repli"):
            batch = build_partition_batch(ds.graph, labels, scheme=scheme)
            t0 = time.time()
            _, emb = train_local(ds, batch, cfg, epochs=args.epochs, lr=5e-3)
            res = train_classifier(ds, emb, epochs=120)
            print(f"{method:14s} k={args.k} {scheme:5s}: "
                  f"test={res['test']:.3f} "
                  f"(cut={rep.edge_cut_pct:.1f}% "
                  f"comps={rep.total_components} "
                  f"iso={rep.total_isolated}, {time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
