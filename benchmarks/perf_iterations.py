"""§Perf hillclimbing driver: runs each documented iteration on the three
chosen (arch × shape) pairs and prints before/after roofline terms.

Run with the 512-device env (it imports dryrun first, which sets it):

    PYTHONPATH=src python -m benchmarks.perf_iterations [--only P1,P2]

Iterations (hypothesis -> change -> measure, EXPERIMENTS.md §Perf):
  P1 nemotron-4-340b × train_4k : dp_tp -> fsdp_tp (fit in HBM)
  P2 qwen3-4b       × train_4k : dp_tp -> ddp_fsdp (kill TP all-reduces)
  P3 qwen2-moe      × train_4k : pad experts 60->64 (shard the E axis)
  P4 deepseek-v2    × train_4k : fsdp_tp (worst absolute roofline)
"""
# Must import dryrun FIRST: it pins XLA_FLAGS before jax initializes.
from repro.launch import dryrun  # noqa: E402  (sets 512 host devices)

import argparse
import dataclasses as dc
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def _summ(rec):
    if not rec.get("ok"):
        return f"FAILED {rec.get('error', '')[:80]}"
    t = rec["roofline"]
    peak = (rec.get("memory", {}).get("peak_bytes") or 0) / 1e9
    return (f"compute={t['compute_s']:.3g}s mem={t['memory_s']:.3g}s "
            f"coll={t['collective_s']:.3g}s dom={t['dominant']} "
            f"peak={peak:.1f}GB frac={rec.get('useful_flops_frac'):.3g}")


def _baseline(arch, shape):
    path = os.path.join(ART, f"{arch}__{shape}__pod16x16__dp_tp.json")
    with open(path) as f:
        return json.load(f)


ITERATIONS = {
    "P1": dict(arch="nemotron4_340b", shape="train_4k", mode="fsdp_tp",
               tag="", transform=None,
               hypothesis="213GB/chip is 3x params+opt replicated over data;"
               " ZeRO-3 sharding over the 16 data rows divides weight+opt"
               " storage by 16 -> ~13GB, at the cost of per-layer weight"
               " all-gathers (params bf16 ~42GB/16 gathered per step)"),
    "P2": dict(arch="qwen3_4b", shape="train_4k", mode="ddp_fsdp",
               tag="", transform=None,
               hypothesis="TP=16 on a 4B model costs 6.5GB/layer/device of"
               " activation all-reduce (237GB/step); pure DP over all 256"
               " chips (batch 1/chip) with ZeRO-3 storage keeps only"
               " grad reduce + weight gathers ~ 3x param bytes ~ 2.6GB"
               " -> ~50x less collective traffic"),
    "P3": dict(arch="qwen2_moe_a2p7b", shape="train_4k", mode="dp_tp",
               tag="__epad64",
               transform=lambda c: dc.replace(c, experts_pad_to=64),
               hypothesis="E=60 does not divide model=16, so the guard"
               " replicated ALL expert weights and XLA all-reduces the full"
               " [E,C,d] buffers (570GB/step, frac=0.105). Padding to 64"
               " dummy experts shards the E axis 16-way: expert compute /16"
               " and the dispatch becomes sharded"),
    "P4": dict(arch="deepseek_v2_236b", shape="train_4k", mode="fsdp_tp",
               tag="", transform=None,
               hypothesis="worst absolute roofline (coll=1230s): 236B total"
               " params replicated over data drive both 154GB peak and"
               " giant all-reduces; fsdp_tp shards storage 16-way"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()
    chosen = args.only.split(",") if args.only else list(ITERATIONS)
    for name in chosen:
        it = ITERATIONS[name]
        base = _baseline(it["arch"], it["shape"])
        print(f"\n=== {name}: {it['arch']} × {it['shape']} ===")
        print(f"hypothesis: {it['hypothesis']}")
        print(f"BEFORE (dp_tp): {_summ(base)}")
        rec = dryrun.run_one(it["arch"], it["shape"], multi_pod=False,
                             mode=it["mode"], out_dir=ART, verbose=False,
                             tag=it["tag"], cfg_transform=it["transform"])
        print(f"AFTER  ({it['mode']}{it['tag']}): {_summ(rec)}")


if __name__ == "__main__":
    main()
