"""Paper Fig 7: training time vs number of partitions (Inner vs Repli),
plus the jnp-vs-Pallas-kernel aggregation trajectory.

The paper's claim: because LF training is communication-free, the wall time
of the slowest partition drops steeply with k (vs synchronized frameworks
where communication keeps it flat). Runs through ``repro.pipeline`` (shared
partition cache, classifier stage skipped) and reads the train-stage timing
from the PipelineReport.

Since the aggregation kernel grew a custom VJP (DESIGN.md §11),
``use_kernel=True`` is a real training path, so every grid also times it
against the jnp segment-sum path. On CPU the kernel executes in interpret
mode — those numbers anchor the *trajectory* (and catch pathological
regressions), not TPU performance; the kernel rows therefore run at the
smallest k only in the full grid, and on a reduced graph in ``--smoke``.

    PYTHONPATH=src python -m benchmarks.training_time           # fast grid
    PYTHONPATH=src python -m benchmarks.training_time --full
    PYTHONPATH=src python -m benchmarks.training_time --smoke   # CI gate

Besides the CSV block, every run appends its rows to
``benchmarks/artifacts/BENCH_training_time.json`` (k, scheme, kernel,
epochs, wall seconds, timestamp), accumulating the training-perf trajectory
across commits the same way ``BENCH_partition_time.json`` does for
partitioning.
"""
from __future__ import annotations

import argparse
import os

from .common import (ARTIFACTS, append_bench_json, arxiv_like, emit,
                     partition_store)

BENCH_JSON = os.path.join(ARTIFACTS, "BENCH_training_time.json")


def _time_one(ds, k: int, scheme: str, use_kernel: bool, epochs: int,
              autotune: bool = False):
    from repro.pipeline import Pipeline, PipelineConfig
    cfg = PipelineConfig(
        method="leiden_fusion", k=k, seed=0, scheme=scheme,
        mode="local", model="gcn", use_kernel=use_kernel,
        kernel_autotune=autotune,
        hidden_dim=128, embed_dim=128,
        num_layers=3, dropout=0.0, epochs=epochs, lr=5e-3,
        classifier_epochs=0,          # timing only
        collect_hlo=False,
        # unsharded: the per_machine_s = wall/k math below assumes
        # the k partitions train sequentially on ONE device
        shard_data_axis=False)
    report = Pipeline(cfg, store=partition_store()).run(ds)
    total = report.timings["train"]
    strategies = sorted({v["strategy"]
                         for v in (report.kernel or {}).values()})
    return {"k": k, "scheme": scheme,
            "kernel": use_kernel, "epochs": epochs,
            "strategy": "+".join(strategies) if strategies else "jnp",
            "wall_s": round(total, 2),
            # on k real machines each trains ONLY its own subgraph with
            # zero communication (proven by the zero-collective HLO), so
            # per-machine time is the sequential wall divided by k:
            "per_machine_s": round(total / k, 2),
            "n_pad": report.shapes["n_pad"],
            "e_pad": report.shapes["e_pad"]}


def run(fast: bool = True, smoke: bool = False):
    rows = []
    if smoke:
        # CI training-perf gate: reduced graph, both aggregation paths.
        # The kernel row autotunes (cached across runs), so it times the
        # strategy the dispatcher would really pick on this backend — the
        # pair feeds the one-way perf ratchet (benchmarks.ratchet).
        ds = arxiv_like(n=1200)
        for use_kernel in (False, True):
            rows.append(_time_one(ds, k=4, scheme="repli",
                                  use_kernel=use_kernel, epochs=5,
                                  autotune=use_kernel))
    else:
        ds = arxiv_like()
        ks = (2, 8, 16) if fast else (2, 4, 8, 16)
        epochs = 15
        for k in ks:
            for scheme in ("inner", "repli"):
                rows.append(_time_one(ds, k, scheme, False, epochs))
        # autotuned kernel anchor at the smallest k per scheme
        for scheme in ("inner", "repli"):
            rows.append(_time_one(ds, min(ks), scheme, True, epochs,
                                  autotune=True))
    emit("fig7_training_time", rows)
    append_bench_json(BENCH_JSON, rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--full", action="store_true",
                    help="paper-sized k grid")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: reduced graph, jnp vs kernel rows only")
    args = ap.parse_args()
    run(fast=not args.full, smoke=args.smoke)


if __name__ == "__main__":
    main()
