"""Paper Fig 7: training time vs number of partitions (Inner vs Repli).

The paper's claim: because LF training is communication-free, the wall time
of the slowest partition drops steeply with k (vs synchronized frameworks
where communication keeps it flat). Runs through ``repro.pipeline`` (shared
partition cache, classifier stage skipped) and reads the train-stage timing
from the PipelineReport."""
from __future__ import annotations

from .common import arxiv_like, emit, partition_store


def run(fast: bool = True):
    from repro.pipeline import Pipeline, PipelineConfig
    ds = arxiv_like()
    ks = (2, 8, 16) if fast else (2, 4, 8, 16)
    epochs = 15
    rows = []
    for k in ks:
        for scheme in ("inner", "repli"):
            cfg = PipelineConfig(
                method="leiden_fusion", k=k, seed=0, scheme=scheme,
                mode="local", model="gcn", hidden_dim=128, embed_dim=128,
                num_layers=3, dropout=0.0, epochs=epochs, lr=5e-3,
                classifier_epochs=0,          # timing only
                collect_hlo=False,
                # unsharded: the per_machine_s = wall/k math below assumes
                # the k partitions train sequentially on ONE device
                shard_data_axis=False)
            report = Pipeline(cfg, store=partition_store()).run(ds)
            total = report.timings["train"]
            rows.append({"k": k, "scheme": scheme, "epochs": epochs,
                         "wall_s": round(total, 2),
                         # on k real machines each trains ONLY its own
                         # subgraph with zero communication (proven by the
                         # zero-collective HLO), so per-machine time is the
                         # sequential wall divided by k:
                         "per_machine_s": round(total / k, 2),
                         "n_pad": report.shapes["n_pad"],
                         "e_pad": report.shapes["e_pad"]})
    emit("fig7_training_time", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
