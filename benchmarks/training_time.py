"""Paper Fig 7: training time vs number of partitions (Inner vs Repli).

The paper's claim: because LF training is communication-free, the wall time
of the slowest partition drops steeply with k (vs synchronized frameworks
where communication keeps it flat). We measure per-partition step time for
the LF scheme, plus the synchronized halo-exchange baseline's collective
bytes per step from its lowered HLO (the cost DGL-style training pays)."""
from __future__ import annotations

import time

from .common import arxiv_like, emit


def run(fast: bool = True):
    import jax
    import jax.numpy as jnp
    from repro.core import (build_partition_batch, leiden_fusion)
    from repro.gnn import GNNConfig, train_local
    ds = arxiv_like()
    ks = (2, 8, 16) if fast else (2, 4, 8, 16)
    epochs = 15
    rows = []
    for k in ks:
        labels = leiden_fusion(ds.graph, k, seed=0)
        for scheme in ("inner", "repli"):
            batch = build_partition_batch(ds.graph, labels, scheme=scheme)
            cfg = GNNConfig(kind="gcn", feature_dim=ds.features.shape[1],
                            hidden_dim=128, embed_dim=128, num_layers=3,
                            dropout=0.0)
            t0 = time.time()
            train_local(ds, batch, cfg, epochs=epochs, lr=5e-3)
            total = time.time() - t0
            rows.append({"k": k, "scheme": scheme, "epochs": epochs,
                         "wall_s": round(total, 2),
                         # on k real machines each trains ONLY its own
                         # subgraph with zero communication (proven by the
                         # zero-collective HLO), so per-machine time is the
                         # sequential wall divided by k:
                         "per_machine_s": round(total / k, 2),
                         "n_pad": batch.n_pad, "e_pad": batch.e_pad})
    emit("fig7_training_time", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
