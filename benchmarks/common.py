"""Shared helpers for the benchmark modules."""
from __future__ import annotations

import functools
import json
import os
import platform
import subprocess
import sys
import time
from typing import Dict, List

import numpy as np

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")
PARTITION_CACHE = os.path.join(ARTIFACTS, "partition_cache")


@functools.lru_cache(maxsize=1)
def provenance() -> Dict[str, str]:
    """Environment stamp merged into every BENCH row (DESIGN.md §16): git
    sha, platform string, jax version, and the obs trace-schema version, so
    a trajectory point can always be traced back to the code and machine
    that produced it. Every field degrades to ``"unknown"`` rather than
    failing — benchmarks must run from a tarball too."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    try:
        import jax
        jax_version = jax.__version__
    except ImportError:
        jax_version = "unknown"
    try:
        from repro.obs import SCHEMA_VERSION
        obs_schema = SCHEMA_VERSION
    except ImportError:
        obs_schema = 0
    return {"git_sha": sha or "unknown",
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax_version": jax_version,
            "obs_schema_version": obs_schema}


@functools.lru_cache(maxsize=1)
def partition_store():
    """Shared partition artifact store: every benchmark module reuses the
    same cached partitions (a grid over model/scheme/epochs partitions each
    (method, k, seed) exactly once)."""
    from repro.pipeline import PartitionArtifactStore
    return PartitionArtifactStore(PARTITION_CACHE)


def append_bench_json(path: str, rows: List[Dict]) -> None:
    """Append rows (stamped with one shared timestamp) to a JSON
    perf-trajectory file — the BENCH_*.json pattern shared by
    partition_time and training_time. The rewrite is atomic (tmp file +
    ``os.replace``) so an interrupted run cannot truncate the history."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (OSError, ValueError):
            history = []
    stamp = time.time()
    prov = provenance()
    history.extend({**r, "ts": stamp, "provenance": prov} for r in rows)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(history, f, indent=2)
    os.replace(tmp, path)


def emit(table: str, rows: List[Dict], keys: List[str] | None = None) -> None:
    """Print a named CSV block (also saved under artifacts/<table>.csv)."""
    if not rows:
        print(f"# {table}: EMPTY")
        return
    keys = keys or list(rows[0].keys())
    lines = [",".join(keys)]
    for r in rows:
        lines.append(",".join(_fmt(r.get(k)) for k in keys))
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, f"{table}.csv"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"# --- {table} ---")
    for ln in lines:
        print(ln)
    sys.stdout.flush()


def _fmt(v) -> str:
    if v is None:
        return ""
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


@functools.lru_cache(maxsize=4)
def arxiv_like(n: int = 8000, seed: int = 0):
    from repro.core import make_arxiv_like
    return make_arxiv_like(n=n, seed=seed)


@functools.lru_cache(maxsize=4)
def proteins_like(n: int = 3000, seed: int = 1):
    from repro.core import make_proteins_like
    return make_proteins_like(n=n, seed=seed)


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
