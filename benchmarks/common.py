"""Shared helpers for the benchmark modules."""
from __future__ import annotations

import functools
import json
import os
import sys
import time
from typing import Dict, List

import numpy as np

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")
PARTITION_CACHE = os.path.join(ARTIFACTS, "partition_cache")


@functools.lru_cache(maxsize=1)
def partition_store():
    """Shared partition artifact store: every benchmark module reuses the
    same cached partitions (a grid over model/scheme/epochs partitions each
    (method, k, seed) exactly once)."""
    from repro.pipeline import PartitionArtifactStore
    return PartitionArtifactStore(PARTITION_CACHE)


def append_bench_json(path: str, rows: List[Dict]) -> None:
    """Append rows (stamped with one shared timestamp) to a JSON
    perf-trajectory file — the BENCH_*.json pattern shared by
    partition_time and training_time. The rewrite is atomic (tmp file +
    ``os.replace``) so an interrupted run cannot truncate the history."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (OSError, ValueError):
            history = []
    stamp = time.time()
    history.extend({**r, "ts": stamp} for r in rows)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(history, f, indent=2)
    os.replace(tmp, path)


def emit(table: str, rows: List[Dict], keys: List[str] | None = None) -> None:
    """Print a named CSV block (also saved under artifacts/<table>.csv)."""
    if not rows:
        print(f"# {table}: EMPTY")
        return
    keys = keys or list(rows[0].keys())
    lines = [",".join(keys)]
    for r in rows:
        lines.append(",".join(_fmt(r.get(k)) for k in keys))
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, f"{table}.csv"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"# --- {table} ---")
    for ln in lines:
        print(ln)
    sys.stdout.flush()


def _fmt(v) -> str:
    if v is None:
        return ""
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


@functools.lru_cache(maxsize=4)
def arxiv_like(n: int = 8000, seed: int = 0):
    from repro.core import make_arxiv_like
    return make_arxiv_like(n=n, seed=seed)


@functools.lru_cache(maxsize=4)
def proteins_like(n: int = 3000, seed: int = 1):
    from repro.core import make_proteins_like
    return make_proteins_like(n=n, seed=seed)


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
