"""Paper Fig 6a/6b (GCN/SAGE accuracy on Arxiv, Inner vs Repli) and Table 2
(SAGE ROC-AUC on the dense Proteins graph, Inner only).

Runs through ``repro.pipeline`` with the shared benchmark partition cache,
so each (method, k, seed) is partitioned exactly once across the whole
model/scheme grid."""
from __future__ import annotations

from .common import arxiv_like, emit, partition_store, proteins_like


def _pipeline_config(method, k, scheme, model, epochs, seed=0):
    from repro.pipeline import PipelineConfig
    return PipelineConfig(
        method=method, k=k, seed=seed, scheme=scheme, mode="local",
        model=model, hidden_dim=128, embed_dim=128, num_layers=3,
        dropout=0.3, epochs=epochs, lr=5e-3, classifier_epochs=120,
        collect_hlo=False)


def _run_one(ds, method, k, scheme, model, epochs, seed=0):
    from repro.pipeline import Pipeline
    cfg = _pipeline_config(method, k, scheme, model, epochs, seed)
    report = Pipeline(cfg, store=partition_store()).run(ds)
    return report.accuracy


def centralized_reference(ds, model, epochs, seed=0):
    return _run_one(ds, "single", 1, "inner", model, epochs, seed)


def run(fast: bool = True, dataset: str = "arxiv_like"):
    ds = arxiv_like() if dataset == "arxiv_like" else proteins_like()
    epochs = 40 if fast else 80
    models = ("gcn",) if fast else ("gcn", "sage")
    if dataset == "proteins_like":
        models = ("sage",)                      # paper Table 2
        schemes = ("inner",)                    # Repli too dense (paper §5.2)
    else:
        schemes = ("inner", "repli")
    ks = (2, 8, 16) if fast else (2, 4, 8, 16)
    methods = ("lpa", "metis", "leiden_fusion")
    rows = []
    for model in models:
        ref = centralized_reference(ds, model, epochs)
        rows.append({"dataset": ds.name, "model": model,
                     "method": "centralized", "k": 1, "scheme": "-",
                     "test": ref["test"], "val": ref["val"]})
        for k in ks:
            for method in methods:
                for scheme in schemes:
                    res = _run_one(ds, method, k, scheme, model, epochs)
                    rows.append({"dataset": ds.name, "model": model,
                                 "method": method, "k": k, "scheme": scheme,
                                 "test": res["test"], "val": res["val"]})
    emit(f"fig6_accuracy_{dataset}", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
    run(fast=False, dataset="proteins_like")
