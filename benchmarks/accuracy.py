"""Paper Fig 6a/6b (GCN/SAGE accuracy on Arxiv, Inner vs Repli) and Table 2
(SAGE ROC-AUC on the dense Proteins graph, Inner only)."""
from __future__ import annotations

from .common import arxiv_like, emit, proteins_like


def _run_one(ds, method, k, scheme, model, epochs, seed=0):
    from repro.core import PARTITIONERS, build_partition_batch
    from repro.gnn import GNNConfig, train_classifier, train_local
    labels = PARTITIONERS[method](ds.graph, k, seed=seed)
    batch = build_partition_batch(ds.graph, labels, scheme=scheme)
    cfg = GNNConfig(kind=model, feature_dim=ds.features.shape[1],
                    hidden_dim=128, embed_dim=128, num_layers=3, dropout=0.3)
    _, emb = train_local(ds, batch, cfg, epochs=epochs, lr=5e-3, seed=seed)
    return train_classifier(ds, emb, epochs=120, seed=seed)


def centralized_reference(ds, model, epochs, seed=0):
    import numpy as np
    from repro.core import build_partition_batch
    from repro.gnn import GNNConfig, train_classifier, train_local
    labels = np.zeros(ds.graph.n, dtype=np.int64)
    batch = build_partition_batch(ds.graph, labels, scheme="inner")
    cfg = GNNConfig(kind=model, feature_dim=ds.features.shape[1],
                    hidden_dim=128, embed_dim=128, num_layers=3, dropout=0.3)
    _, emb = train_local(ds, batch, cfg, epochs=epochs, lr=5e-3, seed=seed)
    return train_classifier(ds, emb, epochs=120, seed=seed)


def run(fast: bool = True, dataset: str = "arxiv_like"):
    ds = arxiv_like() if dataset == "arxiv_like" else proteins_like()
    epochs = 40 if fast else 80
    models = ("gcn",) if fast else ("gcn", "sage")
    if dataset == "proteins_like":
        models = ("sage",)                      # paper Table 2
        schemes = ("inner",)                    # Repli too dense (paper §5.2)
    else:
        schemes = ("inner", "repli")
    ks = (2, 8, 16) if fast else (2, 4, 8, 16)
    methods = ("lpa", "metis", "leiden_fusion")
    rows = []
    for model in models:
        ref = centralized_reference(ds, model, epochs)
        rows.append({"dataset": ds.name, "model": model,
                     "method": "centralized", "k": 1, "scheme": "-",
                     "test": ref["test"], "val": ref["val"]})
        for k in ks:
            for method in methods:
                for scheme in schemes:
                    res = _run_one(ds, method, k, scheme, model, epochs)
                    rows.append({"dataset": ds.name, "model": model,
                                 "method": method, "k": k, "scheme": scheme,
                                 "test": res["test"], "val": res["val"]})
    emit(f"fig6_accuracy_{dataset}", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
    run(fast=False, dataset="proteins_like")
