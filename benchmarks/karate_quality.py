"""Paper Table 1 + Fig 3: partition quality of LPA/METIS/Random/LF on the
Zachary karate club, k=2 (isolated nodes, components, edge cuts)."""
from __future__ import annotations

from .common import emit


def run(fast: bool = True):
    from repro.core import evaluate_partition, karate_club, \
        partition_from_spec
    g = karate_club()
    rows = []
    for name in ("lpa", "metis", "random", "leiden_fusion"):
        labels = partition_from_spec(g, name, 2, seed=0).labels
        rep = evaluate_partition(g, labels)
        rows.append({
            "method": name,
            "isolated_p0": rep.isolated_per_part[0],
            "isolated_p1": rep.isolated_per_part[1],
            "components_p0": rep.components_per_part[0],
            "components_p1": rep.components_per_part[1],
            "edge_cuts": int(round(rep.edge_cut_pct / 100 * g.m)),
        })
    emit("table1_karate", rows)
    return rows


if __name__ == "__main__":
    run()
