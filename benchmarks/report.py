"""Render EXPERIMENTS.md tables from the dry-run artifacts (fills the
<!--...--> placeholders)."""
from __future__ import annotations

import glob
import json
import os
import re

from .roofline import DRYRUN_DIR, load_records

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def _f(x, digits=3):
    if x is None:
        return "—"
    if x == 0:
        return "0"
    return f"{x:.{digits}g}"


def roofline_table() -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | dominant "
            "| useful frac | peak GB | fits 16GB |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(load_records(mesh="pod16x16"),
                    key=lambda r: (r.get("arch", ""), r.get("shape", ""))):
        if "workload" in r:
            continue
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | FAILED: "
                        f"{r.get('error','')[:40]} | | | | | | |")
            continue
        t = r["roofline"]
        mem = r.get("memory") or {}
        peak = (mem.get("peak_bytes") or 0) / 1e9
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_f(t['compute_s'])} | "
            f"{_f(t['memory_s'])} | {_f(t['collective_s'])} | "
            f"{t['dominant']} | {_f(r.get('useful_flops_frac'))} | "
            f"{peak:.1f} | {'✅' if peak and peak < 16 else '❌'} |")
    return "\n".join(rows)


def dryrun_matrix() -> str:
    recs = load_records()
    ok = {}
    for r in recs:
        if "workload" in r:
            continue
        ok[(r["arch"], r["shape"], r["mesh"])] = r.get("ok", False)
    archs = sorted({k[0] for k in ok})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    rows = ["| arch | " + " | ".join(shapes) + " |",
            "|---|" + "---|" * len(shapes)]
    for a in archs:
        cells = []
        for s in shapes:
            c1 = ok.get((a, s, "pod16x16"))
            c2 = ok.get((a, s, "pod2x16x16"))
            mark = lambda v: "✅" if v else ("❌" if v is False else "·")
            cells.append(f"{mark(c1)}/{mark(c2)}")
        rows.append(f"| {a} | " + " | ".join(cells) + " |")
    rows.append("")
    rows.append("(cell = single-pod / multi-pod compile)")
    return "\n".join(rows)


def gnn_summary() -> str:
    out = []
    for mesh in ("pod16x16", "pod2x16x16"):
        path = os.path.join(DRYRUN_DIR, f"gnn_lf__{mesh}.json")
        if not os.path.exists(path):
            continue
        r = json.load(open(path))
        line = (f"- **{mesh}** ({r['k_partitions']} partitions, 1/chip): "
                f"LF local step collectives = "
                f"**{r['collectives']['total']} bytes** "
                f"(zero_collectives={r['zero_collectives']})")
        if "sync_baseline_collectives" in r:
            sb = r["sync_baseline_collectives"]["total"]
            line += (f"; synchronized halo baseline = {sb/1e9:.2f} GB/step "
                     f"all-gather traffic per device (p2p lower bound "
                     f"{r.get('halo_p2p_bytes_analytic', 0)/1e6:.1f} MB/step "
                     f"global) — the traffic LF eliminates")
        out.append(line)
    return "\n".join(out)


def fill(marker: str, content: str, text: str) -> str:
    return text.replace(f"<!--{marker}-->", content)


def main():
    with open(EXPERIMENTS) as f:
        text = f.read()
    text = fill("ROOFLINE_TABLE", roofline_table(), text)
    text = fill("DRYRUN_MATRIX", dryrun_matrix(), text)
    text = fill("GNN_DRYRUN", "\n" + gnn_summary(), text)
    with open(EXPERIMENTS, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md tables rendered")


if __name__ == "__main__":
    main()
