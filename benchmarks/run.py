"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run           # fast grid (CI)
    PYTHONPATH=src python -m benchmarks.run --full    # paper-size grid

Each module prints a named CSV block and stores it under
benchmarks/artifacts/. The roofline module additionally requires the dry-run
artifacts (python -m repro.launch.dryrun --all --both-meshes)."""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated module names")
    args = ap.parse_args()
    fast = not args.full

    from . import (accuracy, fusion_ablation, karate_quality,
                   partition_quality, partition_time, roofline,
                   training_time)
    modules = {
        "karate_quality": lambda: karate_quality.run(fast),
        "partition_quality": lambda: partition_quality.run(fast),
        "partition_quality_dense": lambda: partition_quality.run(
            fast, dataset="proteins_like"),
        "partition_time": lambda: partition_time.run(fast),
        "accuracy": lambda: accuracy.run(fast),
        "accuracy_dense": lambda: accuracy.run(fast,
                                               dataset="proteins_like"),
        "training_time": lambda: training_time.run(fast),
        "fusion_ablation": lambda: fusion_ablation.run(fast),
        "roofline": lambda: roofline.run(fast),
    }
    chosen = (args.only.split(",") if args.only else list(modules))
    t0 = time.time()
    failures = []
    for name in chosen:
        print(f"\n==== {name} ====", flush=True)
        t1 = time.time()
        try:
            modules[name]()
        except Exception as e:                                # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"# FAILED {name}: {e!r}", flush=True)
        print(f"# {name}: {time.time() - t1:.1f}s", flush=True)
    print(f"\n# total: {time.time() - t0:.1f}s; failures: {failures}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
