"""Paper Fig 4 (arxiv) + Fig 5 (proteins): subgraph quality metrics vs k for
each partitioning method — edge-cut %, components, isolated nodes, node/edge
balance, replication factor."""
from __future__ import annotations

from .common import arxiv_like, emit, proteins_like, timer


def run(fast: bool = True, dataset: str = "arxiv_like"):
    from repro.core import PARTITIONERS, evaluate_partition
    ds = arxiv_like() if dataset == "arxiv_like" else proteins_like()
    ks = (2, 8, 16) if fast else (2, 4, 8, 16)
    methods = ("lpa", "metis", "random", "leiden_fusion")
    rows = []
    for k in ks:
        for m in methods:
            with timer() as t:
                labels = PARTITIONERS[m](ds.graph, k, seed=0)
            rep = evaluate_partition(ds.graph, labels)
            rows.append({"dataset": ds.name, "k": k, "method": m,
                         **rep.as_dict(), "partition_time_s": t.s})
    emit(f"fig4_quality_{dataset}", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
    run(fast=False, dataset="proteins_like")
