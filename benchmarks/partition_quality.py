"""Paper Fig 4 (arxiv) + Fig 5 (proteins): subgraph quality metrics vs k for
each partitioning method — edge-cut %, components, isolated nodes, node/edge
balance, replication factor."""
from __future__ import annotations

from .common import arxiv_like, emit, proteins_like


def run(fast: bool = True, dataset: str = "arxiv_like"):
    from repro.core import evaluate_partition, partition_from_spec
    ds = arxiv_like() if dataset == "arxiv_like" else proteins_like()
    ks = (2, 8, 16) if fast else (2, 4, 8, 16)
    # spec strings: the +f combinator variants ride along for free
    methods = ("lpa", "metis", "random", "leiden_fusion")
    rows = []
    for k in ks:
        for m in methods:
            res = partition_from_spec(ds.graph, m, k, seed=0)
            rep = evaluate_partition(ds.graph, res.labels)
            rows.append({"dataset": ds.name, "k": k, "method": res.spec,
                         **rep.as_dict(), "partition_time_s": res.seconds})
    emit(f"fig4_quality_{dataset}", rows)
    return rows


if __name__ == "__main__":
    run(fast=False)
    run(fast=False, dataset="proteins_like")
