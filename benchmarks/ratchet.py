"""One-way training-perf ratchet (the CI gate for ROADMAP item 4).

Reads the latest paired smoke rows from
``benchmarks/artifacts/BENCH_training_time.json`` (same k/scheme/epochs,
``kernel: true`` vs ``kernel: false`` stamped by one run) and FAILS unless

    kernel_wall <= jnp_wall * max_ratio

where ``max_ratio`` comes from ``benchmarks/waivers.json`` for the current
backend (default 1.0 — the kernel path must WIN or tie). Waivers are the
explicit, documented escape hatch per backend; there is no silent slack.
The trajectory can only move one way: once the kernel path beats jnp on a
backend, a regression fails the build.

    PYTHONPATH=src python -m benchmarks.training_time --smoke   # produce
    PYTHONPATH=src python -m benchmarks.ratchet                 # gate

Exit codes: 0 pass, 1 regression, 2 missing/unpaired data (the smoke run
must happen first — CI orders the steps).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .common import ARTIFACTS

BENCH_JSON = os.path.join(ARTIFACTS, "BENCH_training_time.json")
WAIVERS_JSON = os.path.join(os.path.dirname(__file__), "waivers.json")


def load_waiver(backend: str) -> tuple[float, str]:
    """(max_ratio, reason) for ``backend`` from the waiver table."""
    try:
        with open(WAIVERS_JSON) as f:
            table = json.load(f).get("training_time", {})
    except (OSError, ValueError):
        table = {}
    entry = table.get("backends", {}).get(backend)
    if entry:
        return float(entry["max_ratio"]), entry.get("reason", "")
    return float(table.get("default_max_ratio", 1.0)), "default (no waiver)"


def latest_smoke_pair(history: list) -> tuple[dict, dict] | None:
    """Most recent (jnp_row, kernel_row) sharing ts/k/scheme/epochs."""
    by_ts: dict = {}
    for row in history:
        by_ts.setdefault(row.get("ts"), []).append(row)
    for ts in sorted(by_ts, key=lambda t: t or 0, reverse=True):
        rows = by_ts[ts]
        for kr in rows:
            if not kr.get("kernel"):
                continue
            for jr in rows:
                if (not jr.get("kernel")
                        and jr.get("k") == kr.get("k")
                        and jr.get("scheme") == kr.get("scheme")
                        and jr.get("epochs") == kr.get("epochs")):
                    return jr, kr
    return None


def check(verbose: bool = True) -> int:
    import jax
    backend = jax.default_backend()
    if not os.path.exists(BENCH_JSON):
        print(f"ratchet: no {BENCH_JSON} — run "
              "`python -m benchmarks.training_time --smoke` first")
        return 2
    with open(BENCH_JSON) as f:
        history = json.load(f)
    pair = latest_smoke_pair(history)
    if pair is None:
        print("ratchet: no paired kernel/jnp rows in the trajectory")
        return 2
    jnp_row, kernel_row = pair
    ratio = kernel_row["wall_s"] / max(jnp_row["wall_s"], 1e-9)
    max_ratio, reason = load_waiver(backend)
    ok = ratio <= max_ratio
    if verbose:
        print(f"ratchet[{backend}]: kernel {kernel_row['wall_s']}s "
              f"(strategy={kernel_row.get('strategy', '?')}) vs "
              f"jnp {jnp_row['wall_s']}s at k={kernel_row['k']} "
              f"scheme={kernel_row['scheme']} epochs={kernel_row['epochs']} "
              f"-> ratio {ratio:.3f} (max {max_ratio:.2f})")
        if max_ratio != 1.0:
            print(f"ratchet[{backend}]: waiver active — {reason}")
        verdict = ("PASS" if ok
                   else "FAIL — kernel path regressed past the waiver "
                        "ceiling")
        print(f"ratchet[{backend}]: {verdict}")
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.parse_args()
    sys.exit(check())


if __name__ == "__main__":
    main()
