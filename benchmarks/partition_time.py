"""Paper Table 3: partitioning time vs k. Reproduces the paper's qualitative
claims: LF constant-or-faster with larger k (greedy merge does less work),
LPA growing with k, METIS flat.

    PYTHONPATH=src python -m benchmarks.partition_time              # Table 3
    PYTHONPATH=src python -m benchmarks.partition_time --scale 12.5 # 100k LF
    PYTHONPATH=src python -m benchmarks.partition_time --scale 12.5 --smoke

``--scale`` multiplies the 8000-node benchmark graph. Scaled runs default to
``leiden_fusion`` + ``fusion_only`` (the vectorized engine); pass
``--all-methods`` to include the LPA/METIS baselines, which are still
node-at-a-time Python and crawl past ~20k nodes. ``--smoke`` is the CI perf
gate: one ``leiden_fusion`` run at k=8 plus the partition-quality
guarantees, failing loudly if a Python-loop regression sneaks back into the
engine.

``--out-of-core`` exercises the mmap GraphStore path instead (DESIGN.md
§15): generation streams a ``--nodes``-node graph (default 10^6) straight
to a chunked CSR bundle on disk, leiden_fusion partitions it
chunk-by-chunk, and every row additionally records ``peak_rss_mb`` — the
process peak resident set at row completion — so the trajectory shows the
RAM the out-of-core path actually held while the in-RAM path at the same
``n`` would have materialized the full edge list.

Besides the CSV block, every run appends its rows to
``benchmarks/artifacts/BENCH_partition_time.json`` (method, k, n, seconds,
timestamp; out-of-core rows add peak_rss_mb), so the perf trajectory
accumulates across runs.
"""
from __future__ import annotations

import argparse
import os
import resource
import time

from .common import ARTIFACTS, append_bench_json, arxiv_like, emit

BENCH_JSON = os.path.join(ARTIFACTS, "BENCH_partition_time.json")
STREAM_DIR = os.path.join(ARTIFACTS, "streamed")


def _peak_rss_mb() -> float:
    """Process high-water resident set in MB (ru_maxrss is KB on Linux)."""
    return round(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024,
                 1)


def run(fast: bool = True, scale: float = 1.0, all_methods: bool = False,
        smoke: bool = False):
    from repro.core import fuse, leiden, partition_from_spec

    n = int(8000 * scale)
    ds = arxiv_like(n=n)
    g = ds.graph
    ks = (8,) if smoke else (2, 4, 8, 16)
    rows = []
    # Leiden preprocessing time, reported separately like the paper's 11.5 s;
    # the same communities then feed the fusion-only rows (the paper's
    # Table 3 numbers are fusion-only — Leiden is precomputed and cached,
    # §5.3).
    t0 = time.time()
    comms = leiden(g, max_community_size=g.n / 16 * 1.05 * 0.5)
    leiden_s = time.time() - t0
    rows.append({"method": "leiden_preprocess", "k": 0, "n": n,
                 "time_s": round(leiden_s, 3)})
    methods = ["leiden_fusion"]
    if all_methods or (scale <= 1.0 and not smoke):
        methods = ["lpa", "metis", "leiden_fusion"]
    smoke_labels = None
    for method in methods:
        for k in ks:
            res = partition_from_spec(g, method, k, seed=0)
            rows.append({"method": res.spec, "k": k, "n": n,
                         "time_s": round(res.seconds, 3)})
            if method == "leiden_fusion":
                smoke_labels = res.labels
    for k in ks:
        t0 = time.time()
        fuse(g, comms, k, (g.n / k) * 1.05)
        rows.append({"method": "fusion_only", "k": k, "n": n,
                     "time_s": round(time.time() - t0, 3)})
    emit("table3_partition_time", rows)
    append_bench_json(BENCH_JSON, rows)
    print(f"# leiden preprocessing: {leiden_s:.1f}s (paper: 11.5s on Arxiv)")
    if smoke:
        _smoke_check(g, ks[0], smoke_labels)
    return rows


def _smoke_check(g, k: int, labels) -> None:
    """CI gate: the scaled leiden_fusion partition must uphold the paper's
    guarantees (one component per partition, no isolated nodes)."""
    from repro.core import evaluate_partition
    rep = evaluate_partition(g, labels)
    assert rep.k == k, rep
    assert rep.max_components == 1, rep
    assert rep.total_isolated == 0, rep
    print(f"# perf-smoke OK: n={g.n} k={k} cut={rep.edge_cut_pct:.1f}% "
          f"balance={rep.node_balance:.2f}")


def run_out_of_core(nodes: int = 1_000_000, smoke: bool = False,
                    out_dir: str | None = None):
    """Stream-generate a ``nodes``-node graph to a chunked mmap CSR bundle
    and partition it out-of-core, recording wall time and peak RSS per row.
    """
    from repro.core import evaluate_partition, partition_from_spec
    from repro.pipeline.datasets import make_arxiv_like_stream

    out_dir = out_dir or os.path.join(STREAM_DIR, f"arxiv-n{nodes}")
    ks = (8,) if smoke else (8, 16)
    rows = []
    t0 = time.time()
    ds = make_arxiv_like_stream(out_dir=out_dir, n=nodes, seed=0)
    g = ds.graph
    rows.append({"method": "stream_generate", "k": 0, "n": g.n,
                 "time_s": round(time.time() - t0, 3),
                 "peak_rss_mb": _peak_rss_mb()})
    print(f"# streamed bundle: {g!r}")
    labels = None
    for k in ks:
        res = partition_from_spec(g, "leiden_fusion", k, seed=0)
        rows.append({"method": "leiden_fusion[out-of-core]", "k": k,
                     "n": g.n, "time_s": round(res.seconds, 3),
                     "peak_rss_mb": _peak_rss_mb()})
        labels = res.labels
    emit("table3_partition_time_ooc", rows)
    append_bench_json(BENCH_JSON, rows)
    if smoke:
        _smoke_check(g, ks[-1], labels)
    else:
        rep = evaluate_partition(g, labels)
        print(f"# out-of-core quality: cut={rep.edge_cut_pct:.1f}% "
              f"components={rep.total_components} "
              f"isolated={rep.total_isolated} "
              f"balance={rep.node_balance:.2f}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scale", type=float, default=1.0,
                    help="multiply the 8000-node benchmark graph")
    ap.add_argument("--all-methods", action="store_true",
                    help="include the LPA/METIS baselines on scaled graphs")
    ap.add_argument("--smoke", action="store_true",
                    help="CI perf gate: leiden_fusion k=8 only, plus the "
                         "partition-quality guarantees")
    ap.add_argument("--out-of-core", action="store_true",
                    help="stream a --nodes graph to a mmap CSR bundle and "
                         "partition it chunk-by-chunk, recording peak RSS "
                         "per row (DESIGN.md §15)")
    ap.add_argument("--nodes", type=int, default=1_000_000,
                    help="node count for --out-of-core runs")
    args = ap.parse_args()
    if args.out_of_core:
        run_out_of_core(nodes=args.nodes, smoke=args.smoke)
    else:
        run(scale=args.scale, all_methods=args.all_methods, smoke=args.smoke)


if __name__ == "__main__":
    main()
