"""Paper Table 3: partitioning time vs k. Reproduces the paper's qualitative
claims: LF constant-or-faster with larger k (greedy merge does less work),
LPA growing with k, METIS flat."""
from __future__ import annotations

import time

from .common import arxiv_like, emit


def run(fast: bool = True):
    from repro.core import leiden, partition_from_spec
    ds = arxiv_like()
    ks = (2, 4, 8, 16)
    rows = []
    # Leiden preprocessing time, reported separately like the paper's 11.5 s
    t0 = time.time()
    leiden(ds.graph, max_community_size=ds.graph.n / 16 * 1.05 * 0.5)
    leiden_s = time.time() - t0
    for method in ("lpa", "metis", "leiden_fusion"):
        for k in ks:
            res = partition_from_spec(ds.graph, method, k, seed=0)
            rows.append({"method": res.spec, "k": k,
                         "time_s": round(res.seconds, 2)})
    # the paper's Table 3 numbers are fusion-only (Leiden communities are
    # precomputed and cached, §5.3) — measure that separately:
    from repro.core import fuse, leiden
    comms = leiden(ds.graph, max_community_size=ds.graph.n / 16 * 1.05 * 0.5)
    for k in ks:
        t0 = time.time()
        fuse(ds.graph, comms, k, (ds.graph.n / k) * 1.05)
        rows.append({"method": "fusion_only", "k": k,
                     "time_s": round(time.time() - t0, 2)})
    emit("table3_partition_time", rows)
    print(f"# leiden preprocessing: {leiden_s:.1f}s (paper: 11.5s on Arxiv)")
    return rows


if __name__ == "__main__":
    run()
