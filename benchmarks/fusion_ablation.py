"""Paper Tables 4-5: the +F fusion operator applied to METIS and LPA at k=16
— partitioning time, edge cuts before/after fusion, and accuracy."""
from __future__ import annotations

import time

from .common import arxiv_like, emit


def run(fast: bool = True):
    from repro.core import (build_partition_batch, evaluate_partition,
                            partition_from_spec, split_into_components, fuse)
    from repro.gnn import GNNConfig, train_classifier, train_local
    ds = arxiv_like()
    k = 16
    rows = []
    acc_rows = []
    epochs = 40 if fast else 80
    for base in ("metis", "lpa", "leiden_fusion"):
        if base == "leiden_fusion":
            res = partition_from_spec(ds.graph, base, k, seed=0)
            labels_f = res.labels
            cut_before = None
            fusion_time = res.seconds
        else:
            # base alone for the "before" cut, then its "+f" spec variant
            labels0 = partition_from_spec(ds.graph, base, k, seed=0).labels
            cut_before = evaluate_partition(ds.graph, labels0).edge_cut_pct
            t1 = time.time()
            comms = split_into_components(ds.graph, labels0)
            labels_f = fuse(ds.graph, comms, k,
                            (ds.graph.n / k) * 1.05)
            fusion_time = time.time() - t1
        rep = evaluate_partition(ds.graph, labels_f)
        rows.append({"method": f"{base}+F", "fusion_time_s":
                     round(fusion_time, 2),
                     "edge_cut_before_pct": cut_before,
                     "edge_cut_after_pct": rep.edge_cut_pct,
                     "max_components": rep.max_components,
                     "total_isolated": rep.total_isolated})
        # accuracy after fusion (Table 5)
        for scheme in ("inner", "repli"):
            batch = build_partition_batch(ds.graph, labels_f, scheme=scheme)
            cfg = GNNConfig(kind="gcn", feature_dim=ds.features.shape[1],
                            hidden_dim=128, embed_dim=128, num_layers=3,
                            dropout=0.3)
            _, emb = train_local(ds, batch, cfg, epochs=epochs, lr=5e-3)
            res = train_classifier(ds, emb, epochs=120)
            acc_rows.append({"method": f"{base}+F", "scheme": scheme,
                             "test": res["test"]})
    emit("table4_fusion_on_others", rows)
    emit("table5_fusion_accuracy", acc_rows)
    return rows, acc_rows


if __name__ == "__main__":
    run(fast=False)
