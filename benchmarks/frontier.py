import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
# The two lines above MUST run before jax is first imported (sync/stale need
# one fake device per partition on CPU), so this module is standalone — it is
# deliberately NOT in the benchmarks.run registry, where jax is already up.
"""The communication-vs-accuracy frontier: local <- stale(period=N) -> sync.

Sweeps the three training modes over one partitioned graph — the zero-
communication paper mode, the every-step halo-exchange baseline, and the
stale(period=N) middle ground for N in {1, 2, 4, 8, 16} — and records, per
point: collective bytes per step / per epoch (from the lowered HLO, not
estimates), classification accuracy, and train wall time. stale(1) must
reproduce the sync bytes and stale's between-exchange step must lower to
ZERO collectives; the per-epoch average is strictly decreasing in the
period (pinned by tests/test_stale_mode.py).

    PYTHONPATH=src python -m benchmarks.frontier            # full sweep
    PYTHONPATH=src python -m benchmarks.frontier --smoke    # CI gate

``--smoke`` runs the reduced grid {local, stale(4), sync} and asserts the
frontier ordering: stale(4) moves strictly fewer bytes per epoch than sync
(and more than local's zero), at test accuracy no worse than local.

Every run appends its rows to ``benchmarks/artifacts/BENCH_frontier.json``
(partitioner spec, mode, period, bytes, accuracy, wall seconds, timestamp) —
the frontier trajectory across commits, same pattern as
BENCH_training_time.json. ``--spec`` sweeps the same grid under any
registered partitioner (``--spec metis``, ``--spec "lpa+f(alpha=0.1)"``);
the canonical spec is recorded in every row so trajectories under different
partitioners stay distinguishable.
"""
import argparse

from .common import ARTIFACTS, append_bench_json, emit, partition_store

BENCH_JSON = os.path.join(ARTIFACTS, "BENCH_frontier.json")

PERIODS = (1, 2, 4, 8, 16)


def _run_point(ds, mode: str, period: int | None, k: int, epochs: int,
               classifier_epochs: int, hidden: int,
               spec: str = "leiden_fusion"):
    from repro.pipeline import Pipeline, PipelineConfig
    cfg = PipelineConfig(
        method=spec, k=k, seed=0, scheme="repli",
        mode=mode, sync_period=period if period is not None else 0,
        model="gcn", hidden_dim=hidden, embed_dim=hidden, num_layers=2,
        dropout=0.0, epochs=epochs, lr=1e-2,
        classifier_epochs=classifier_epochs, collect_hlo=True)
    report = Pipeline(cfg, store=partition_store()).run(ds)
    coll = report.collectives
    return {
        "spec": report.config["method"],   # canonical partitioner spec
        "mode": mode,
        "period": period if mode == "stale" else None,
        "k": k, "epochs": epochs,
        "bytes_per_step": coll.get("total", 0),
        "bytes_per_epoch_avg": coll.get("per_epoch_avg", coll.get("total", 0)),
        "stale_step_bytes": coll.get("stale_step_total", 0),
        "n_exchange_epochs": coll.get("n_exchange_epochs"),
        "val_acc": round(report.accuracy.get("val", 0.0), 4),
        "test_acc": round(report.accuracy.get("test", 0.0), 4),
        "train_wall_s": round(report.timings["train"], 2),
    }


def run(smoke: bool = False, spec: str = "leiden_fusion"):
    from .common import arxiv_like
    k = 4
    if smoke:
        ds = arxiv_like(n=600)
        grid = [("local", None), ("stale", 4), ("sync", None)]
        epochs, classifier_epochs, hidden = 20, 60, 16
    else:
        ds = arxiv_like(n=1600)
        grid = ([("local", None)] + [("stale", p) for p in PERIODS]
                + [("sync", None)])
        epochs, classifier_epochs, hidden = 16, 80, 32
    rows = [_run_point(ds, mode, period, k, epochs, classifier_epochs,
                       hidden, spec=spec)
            for mode, period in grid]
    emit("frontier", rows)
    append_bench_json(BENCH_JSON, rows)

    by = {(r["mode"], r["period"]): r for r in rows}
    if smoke:
        local, st4, sync = by[("local", None)], by[("stale", 4)], by[("sync", None)]
        assert st4["bytes_per_epoch_avg"] < sync["bytes_per_epoch_avg"], (
            f"stale(4) must move strictly fewer bytes/epoch than sync: "
            f"{st4['bytes_per_epoch_avg']} vs {sync['bytes_per_epoch_avg']}")
        assert st4["bytes_per_epoch_avg"] > local["bytes_per_epoch_avg"] == 0, (
            f"stale(4) sits strictly between sync and local's zero bytes: "
            f"{st4['bytes_per_epoch_avg']}")
        assert st4["stale_step_bytes"] == 0, (
            "stale between-exchange step must be collective-free, got "
            f"{st4['stale_step_bytes']}")
        assert st4["test_acc"] >= local["test_acc"], (
            f"stale(4) accuracy must be no worse than local: "
            f"{st4['test_acc']} vs {local['test_acc']}")
        print(f"# frontier smoke OK: local=0 < stale(4)="
              f"{st4['bytes_per_epoch_avg']} < sync="
              f"{sync['bytes_per_epoch_avg']} bytes/epoch; "
              f"acc stale={st4['test_acc']} >= local={local['test_acc']}")
    else:
        stale_rows = [by[("stale", p)] for p in PERIODS]
        avgs = [r["bytes_per_epoch_avg"] for r in stale_rows]
        assert all(a > b for a, b in zip(avgs, avgs[1:])), (
            f"per-epoch bytes must strictly decrease with the period: {avgs}")
        assert avgs[0] == by[("sync", None)]["bytes_per_epoch_avg"], (
            "stale(1) must reproduce the sync traffic")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(
        description="the communication-vs-accuracy frontier: "
                    "local <- stale(period=N) -> sync")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: {local, stale(4), sync} + frontier asserts")
    ap.add_argument("--spec", default="leiden_fusion",
                    help="partitioner spec to sweep (DESIGN.md §9), e.g. "
                         "metis | \"lpa+f(alpha=0.1)\" | "
                         "\"leiden_fusion(resolution=0.5)\"; recorded in "
                         "every BENCH_frontier.json row")
    args = ap.parse_args()
    run(smoke=args.smoke, spec=args.spec)


if __name__ == "__main__":
    main()
