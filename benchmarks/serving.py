import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
# Must run before jax is first imported (the export pipeline trains k=4
# partitions on the mesh data axis), so this module is standalone — it is
# deliberately NOT in the benchmarks.run registry, where jax is already up.
"""Serving benchmark: the online half of the pipeline (DESIGN.md §13).

Exports (or reuses) a 4-partition ``make_arxiv_like`` serving bundle via the
pipeline, replays a Zipf-shaped query stream — including unseen-node queries
answered by the inductive fallback — through the continuous batcher, and
appends one row per configuration to
``benchmarks/artifacts/BENCH_serving.json``:

    throughput_qps, p50_ms, p99_ms, cache_hit_rate,
    warm_compiles, steady_state_recompiles, served_by_source

    PYTHONPATH=src python -m benchmarks.serving            # full replay
    PYTHONPATH=src python -m benchmarks.serving --smoke    # CI gate

``--smoke`` asserts the serving contracts:

* every served label for a known node equals the offline answer key
  (``run_replay(verify=True)`` hard-fails otherwise);
* ``steady_state_recompiles == 0`` — after warmup, no flush may introduce
  a new device shape (measured from the jit caches, not assumed);
* ``cache_hit_rate > 0`` on the Zipf replay — the hot set must actually
  hit the LRU cache;
* p99 latency under a deliberately generous bound (regression tripwire,
  not a performance target).
"""
import argparse
import tempfile

from .common import ARTIFACTS, append_bench_json, partition_store

BENCH_JSON = os.path.join(ARTIFACTS, "BENCH_serving.json")

# Generous CI tripwire: a p99 above this on a 64-query flush means serving
# fell off a cliff (e.g. per-query dispatch or steady-state recompiles),
# not that a shared runner was slow.
SMOKE_P99_BOUND_MS = 2000.0


def _export_bundle(n: int, k: int, epochs: int, classifier_epochs: int,
                   hidden: int, serving_dir: str):
    from .common import arxiv_like
    from repro.pipeline import Pipeline, PipelineConfig
    ds = arxiv_like(n=n)
    cfg = PipelineConfig(
        method="leiden_fusion", k=k, seed=0, mode="local", model="gcn",
        hidden_dim=hidden, embed_dim=hidden, num_layers=2, dropout=0.0,
        epochs=epochs, lr=1e-2, classifier_epochs=classifier_epochs,
        collect_hlo=False, serving_dir=serving_dir)
    report = Pipeline(cfg, store=partition_store()).run(ds)
    return report


def run(smoke: bool = False):
    from repro.serving import (ContinuousBatcher, EmbeddingStore,
                               LruNodeCache, make_zipf_workload, run_replay)
    if smoke:
        n, epochs, classifier_epochs, hidden = 600, 10, 40, 16
        num_queries, cache_capacity = 2000, 256
    else:
        n, epochs, classifier_epochs, hidden = 2000, 20, 80, 32
        num_queries, cache_capacity = 10_000, 512

    with tempfile.TemporaryDirectory(prefix="repro-serving-bench-") as tmp:
        report = _export_bundle(n, 4, epochs, classifier_epochs, hidden, tmp)
        store = EmbeddingStore.load(
            report.serving_path,
            expect_fingerprint=report.partition_fingerprint)
        batcher = ContinuousBatcher(store, cache=LruNodeCache(cache_capacity),
                                    max_batch=64, max_wait_ms=2.0)
        workload = make_zipf_workload(store.n, num_queries=num_queries,
                                      alpha=1.1, unseen_frac=0.02, seed=0)
        row = run_replay(batcher, workload, verify=True)
    row["dataset_n"] = n
    row["test_acc"] = round(report.accuracy.get("test", 0.0), 4)
    append_bench_json(BENCH_JSON, [row])

    if smoke:
        assert row["label_mismatches"] == 0, (
            f"served labels must match the offline answer key exactly, "
            f"got {row['label_mismatches']} mismatches")
        assert row["steady_state_recompiles"] == 0, (
            f"steady state must never recompile (warmup covers every pow2 "
            f"bucket), got {row['steady_state_recompiles']}")
        assert row["cache_hit_rate"] > 0, (
            "the Zipf hot set must hit the LRU cache, got hit_rate=0")
        assert row["p99_ms"] <= SMOKE_P99_BOUND_MS, (
            f"p99 latency {row['p99_ms']}ms blew the {SMOKE_P99_BOUND_MS}ms "
            f"tripwire — serving regressed structurally")
        srcs = row["served_by_source"]
        assert srcs.get("inductive", 0) > 0 and srcs.get("degraded", 0) > 0, (
            f"the replay must exercise the inductive AND degraded paths, "
            f"got {srcs}")
        print(f"# serving smoke OK: {row['throughput_qps']} qps, "
              f"p50={row['p50_ms']}ms p99={row['p99_ms']}ms, "
              f"hit_rate={row['cache_hit_rate']}, "
              f"steady_recompiles=0, exact-match {row['queries']}/"
              f"{row['queries']}")
    else:
        print(f"# serving: {row['throughput_qps']} qps over "
              f"{row['queries']} queries, p50={row['p50_ms']}ms "
              f"p99={row['p99_ms']}ms, hit_rate={row['cache_hit_rate']}, "
              f"sources={row['served_by_source']}")
    return [row]


def main() -> None:
    ap = argparse.ArgumentParser(
        description="partition-sharded serving: Zipf replay through the "
                    "continuous batcher")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: exact-match + zero steady-state "
                         "recompiles + cache hit rate + p99 tripwire")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
