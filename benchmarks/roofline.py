"""§Roofline: aggregate the dry-run artifacts into the per-(arch x shape)
roofline table (single-pod baseline) + the multi-pod compile matrix."""
from __future__ import annotations

import glob
import json
import os

from .common import ARTIFACTS, emit

DRYRUN_DIR = os.path.join(ARTIFACTS, "dryrun")


def load_records(mesh: str | None = None, mode: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        if mode and r.get("mode") != mode:
            continue
        recs.append(r)
    return recs


def run(fast: bool = True):
    rows = []
    for r in load_records(mesh="pod16x16"):
        if "workload" in r:
            continue
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mode": r["mode"], "ok": False,
                         "error": r.get("error", "")[:60]})
            continue
        t = r["roofline"]
        mem = r.get("memory") or {}
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mode": r["mode"],
            "ok": True,
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"], "dominant": t["dominant"],
            "model_flops": r.get("model_flops"),
            "useful_frac": r.get("useful_flops_frac"),
            "peak_gb": (mem.get("peak_bytes") or 0) / 1e9,
            "fits_16gb": ((mem.get("peak_bytes") or 0) < 16e9),
        })
    emit("roofline_single_pod", rows)

    matrix = []
    for r in load_records(mesh="pod2x16x16"):
        if "workload" in r:
            continue
        matrix.append({"arch": r["arch"], "shape": r["shape"],
                       "mode": r["mode"], "ok": r.get("ok", False),
                       "error": (r.get("error") or "")[:60]})
    emit("multipod_compile_matrix", matrix)
    return rows


if __name__ == "__main__":
    run()
