"""§Roofline: aggregate the dry-run artifacts into the per-(arch x shape)
roofline table (single-pod baseline) + the multi-pod compile matrix, plus
the GNN train-step cost table (jnp vs Pallas-kernel aggregation).

The GNN section lowers+compiles the local train step both ways and reads
XLA ``cost_analysis`` FLOPs / bytes — the same no-analytic-estimates rule
as the LM roofline (DESIGN.md §6). It exercises the differentiable kernel
path end-to-end: the compiled step includes the custom-VJP transpose
aggregation and the edge-dot kernel (DESIGN.md §11)."""
from __future__ import annotations

import glob
import json
import os

from .common import ARTIFACTS, arxiv_like, emit

DRYRUN_DIR = os.path.join(ARTIFACTS, "dryrun")


def gnn_train_step_costs():
    """Compiled-HLO cost of one local train step per kernel strategy.

    Besides jnp vs the autotune-resolved kernel path, the table forces each
    kernel strategy via ``autotune.override`` (DESIGN.md §14) so the cost
    model of the fused layer vs the unfused kernel vs the XLA lowering is
    visible side by side — the compiled step includes the custom-VJP
    transpose aggregation and edge-dot kernels in every pallas row."""
    import contextlib
    import jax
    import jax.numpy as jnp
    from repro.core import build_partition_batch, partition_from_spec
    from repro.gnn import (GNNConfig, gather_partition_tensors,
                           init_partition_models, make_local_train_step)
    from repro.gnn.train import _tensors_dict
    from repro.kernels.autotune import KernelConfig, get_config, override
    from repro.launch.hlo_analysis import normalize_cost_analysis
    from repro.optim import adamw_init

    ds = arxiv_like(n=1200)
    labels = partition_from_spec(ds.graph, "leiden_fusion", 4, seed=0).labels
    batch = build_partition_batch(ds.graph, labels, scheme="repli")
    pt = gather_partition_tensors(ds, batch)
    tensors = {n: jnp.asarray(v) for n, v in _tensors_dict(pt).items()}
    resolved = get_config(batch.n_pad, batch.e_pad, 128)
    variants = [
        ("jnp", False, None),
        (f"kernel[{resolved.strategy}]", True, None),   # what dispatch picks
        ("kernel[pallas]", True, KernelConfig(strategy="pallas")),
        ("kernel[pallas_fused]", True, KernelConfig(strategy="pallas_fused")),
    ]
    rows = []
    for label, use_kernel, forced in variants:
        cfg = GNNConfig(kind="gcn", feature_dim=int(ds.features.shape[1]),
                        hidden_dim=128, embed_dim=128, num_layers=3,
                        dropout=0.0, use_kernel=use_kernel)
        params = init_partition_models(jax.random.PRNGKey(0), cfg,
                                       ds.num_classes, batch.k)
        opt = jax.vmap(adamw_init)(params)
        step = jax.jit(make_local_train_step(cfg, False, lr=5e-3))
        keys = jax.random.split(jax.random.PRNGKey(1), batch.k)
        ctx = override(forced) if forced else contextlib.nullcontext()
        with ctx:
            compiled = step.lower(params, opt, tensors, keys).compile()
        ca = normalize_cost_analysis(compiled.cost_analysis())
        flops = float(ca.get("flops", 0.0))
        byts = float(ca.get("bytes accessed", 0.0))
        rows.append({
            "aggregation": label,
            "k": batch.k, "n_pad": batch.n_pad, "e_pad": batch.e_pad,
            "flops": flops, "bytes_accessed": byts,
            "arith_intensity": round(flops / byts, 3) if byts else None,
        })
    emit("gnn_train_step_roofline", rows)
    return rows


def load_records(mesh: str | None = None, mode: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        if mode and r.get("mode") != mode:
            continue
        recs.append(r)
    return recs


def run(fast: bool = True):
    gnn_train_step_costs()
    rows = []
    for r in load_records(mesh="pod16x16"):
        if "workload" in r:
            continue
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mode": r["mode"], "ok": False,
                         "error": r.get("error", "")[:60]})
            continue
        t = r["roofline"]
        mem = r.get("memory") or {}
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mode": r["mode"],
            "ok": True,
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"], "dominant": t["dominant"],
            "model_flops": r.get("model_flops"),
            "useful_frac": r.get("useful_flops_frac"),
            "peak_gb": (mem.get("peak_bytes") or 0) / 1e9,
            "fits_16gb": ((mem.get("peak_bytes") or 0) < 16e9),
        })
    emit("roofline_single_pod", rows)

    matrix = []
    for r in load_records(mesh="pod2x16x16"):
        if "workload" in r:
            continue
        matrix.append({"arch": r["arch"], "shape": r["shape"],
                       "mode": r["mode"], "ok": r.get("ok", False),
                       "error": (r.get("error") or "")[:60]})
    emit("multipod_compile_matrix", matrix)
    return rows


if __name__ == "__main__":
    run()
