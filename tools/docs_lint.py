#!/usr/bin/env python3
"""Docs lint: fail if README/DESIGN cross-references point at missing files.

Checks two reference styles in the repo's top-level markdown docs:

1. Relative markdown links: ``[text](path)`` (external ``http(s)://`` and
   anchors are skipped).
2. Inline-code path references: `` `src/...` ``-style tokens that start with
   a known top-level directory or file and look like a concrete path.

Exit code 1 lists every dangling reference.

    python tools/docs_lint.py [README.md DESIGN.md ...]
"""
from __future__ import annotations

import os
import re
import sys
from typing import List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DEFAULT_DOCS = ["README.md", "DESIGN.md", "ROADMAP.md", "CHANGES.md"]

# Path-ish inline-code tokens must start with one of these to be checked
# (keeps CLI examples like `--cache-dir ~/.cache/...` out of scope).
_PATH_ROOTS = ("src/", "tests/", "benchmarks/", "examples/", "tools/",
               ".github/")
_TOP_FILES = ("README.md", "DESIGN.md", "ROADMAP.md", "PAPER.md",
              "PAPERS.md", "SNIPPETS.md", "CHANGES.md", "requirements.txt",
              "requirements-dev.txt")

_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s#]+)(?:#[^)]*)?\)")
_CODE_SPAN = re.compile(r"`([^`\n]+)`")


def _candidate_paths(text: str) -> List[Tuple[str, str]]:
    """(kind, path) references worth checking."""
    out = []
    for m in _MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        out.append(("link", target))
    for m in _CODE_SPAN.finditer(text):
        token = m.group(1).strip()
        # strip pytest node ids and trailing :line refs
        token = token.split("::")[0]
        token = re.sub(r":\d+$", "", token).rstrip("/")
        if token.startswith("benchmarks/artifacts"):
            continue            # generated at benchmark runtime

        if token in _TOP_FILES:
            out.append(("code", token))
        elif token.startswith(_PATH_ROOTS) and " " not in token:
            # only concrete paths, not glob-ish prose
            if "*" not in token and "<" not in token:
                out.append(("code", token))
    return out


def lint(docs: List[str]) -> List[str]:
    errors = []
    for doc in docs:
        doc_path = os.path.join(REPO, doc)
        if not os.path.exists(doc_path):
            continue
        with open(doc_path, encoding="utf-8") as f:
            text = f.read()
        for kind, ref in _candidate_paths(text):
            target = os.path.normpath(os.path.join(REPO, ref))
            if not os.path.exists(target):
                errors.append(f"{doc}: dangling {kind} reference -> {ref}")
    return errors


def main(argv: List[str]) -> int:
    docs = argv or DEFAULT_DOCS
    errors = lint(docs)
    if errors:
        print("docs lint FAILED:")
        for e in errors:
            print("  " + e)
        return 1
    print(f"docs lint OK ({', '.join(d for d in docs if os.path.exists(os.path.join(REPO, d)))})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
