"""CI RAM-budget smoke for the out-of-core GraphStore (DESIGN.md §15).

Proves two things with one hard ``RLIMIT_AS`` cap:

1. **stream child** — a multi-million-node graph is stream-generated to a
   chunked mmap CSR bundle and partitioned by leiden_fusion (coarsen ->
   partition -> refine) entirely under the cap, upholding the paper's
   partition guarantees (connected parts, no isolated nodes). Optionally
   (``--train``) a small GNN trains end-to-end on the partitioned batch,
   still capped.
2. **inram child** — the pre-GraphStore path (``make_arxiv_like`` + the
   same partition, and with ``--train`` the vmapped all-partitions train
   step) at the same node count must blow the cap with a MemoryError.
   This is what makes the cap meaningful: the same workload on the old
   code path cannot fit, so the stream child passing is evidence of real
   out-of-core behavior, not just a generous limit.

The parent spawns both children (same interpreter, ``--child``), each of
which installs ``resource.setrlimit(RLIMIT_AS, cap)`` before touching any
graph data. Exit 0 iff the stream child succeeds AND the inram child fails
under the cap — a caught MemoryError (exit code 42) when numpy hits the
limit, or a signal death when XLA's native runtime does (its allocator
aborts on a CHECK failure rather than raising).

    python tools/ram_budget_smoke.py                    # 2e6 nodes, 4 GB cap
    python tools/ram_budget_smoke.py --nodes 2000000 --cap-mb 4096
    # end-to-end: + low-memory sequential training under the cap
    python tools/ram_budget_smoke.py --train --nodes 1000000 --cap-mb 7168

Calibration (measured, single-core CPU): at n=2e6 the stream child peaks
~3.7 GB under the 4 GB default while the in-RAM control dies in dataset
generation (its edge-list + feature transients scale with n; the stream
path's partition workspace is a constant ~1.4 GB past the O(n) maps). At
n=1e6 partition-only the two paths are only ~80 MB apart in address
space — RLIMIT_AS counts the mapped bundle and feature file too — so no
cap separates them robustly; pick n >= 2e6 for a trustworthy
partition gate. With ``--train`` the stream child uses the sequential
low-memory trainer (DESIGN.md §15, measured ~6.9 GB peak at n=1e6)
while the in-RAM control keeps the pre-GraphStore vmapped step, which
materializes all k partitions' edge gathers at once (~19 GB measured at
n=1e6, k=8) — 7168 MB cleanly separates old path from new.

The cap is on *address space*, which the mmap'd bundle and feature file do
count toward — that is deliberate: it bounds how much of the bundle the
process may even map at once, a stricter contract than resident-set caps.
"""
from __future__ import annotations

import argparse
import os
import resource
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXIT_EXPECTED_OOM = 42


def _child(mode: str, nodes: int, cap_mb: int, out_dir: str,
           train: bool) -> int:
    cap = cap_mb * 1024 * 1024
    resource.setrlimit(resource.RLIMIT_AS, (cap, cap))
    try:
        from repro.core import evaluate_partition, partition_from_spec
        if mode == "stream":
            from repro.pipeline.datasets import make_arxiv_like_stream
            ds = make_arxiv_like_stream(out_dir=out_dir, n=nodes, seed=0)
        else:
            from repro.core import make_arxiv_like
            ds = make_arxiv_like(n=nodes, seed=0)
        g = ds.graph
        print(f"[{mode}] generated n={g.n} arcs={g.num_arcs}", flush=True)
        res = partition_from_spec(g, "leiden_fusion", 8, seed=0)
        rep = evaluate_partition(g, res.labels)
        assert rep.max_components == 1, rep
        assert rep.total_isolated == 0, rep
        print(f"[{mode}] partitioned k=8 in {res.seconds:.1f}s "
              f"cut={rep.edge_cut_pct:.1f}% balance={rep.node_balance:.2f}",
              flush=True)
        if train:
            from repro.pipeline import Pipeline, PipelineConfig
            # The stream child trains through the sequential low-memory
            # path; the in-RAM control keeps the pre-GraphStore vmapped
            # step (all k partitions' edge gathers at once) — each child
            # runs its era's whole pipeline, old path vs new path.
            cfg = PipelineConfig(
                dataset=mode, method="leiden_fusion", k=8, mode="local",
                epochs=2, classifier_epochs=0, hidden_dim=32, embed_dim=16,
                num_layers=2, dropout=0.0, cache_dir=None, collect_hlo=False,
                shard_data_axis=False, low_memory=(mode == "stream"))
            report = Pipeline(cfg).run(ds)
            print(f"[{mode}] trained end-to-end: "
                  f"n_pad={report.shapes['n_pad']} "
                  f"train={report.timings['train']:.1f}s", flush=True)
        peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
        print(f"[{mode}] OK under RLIMIT_AS={cap_mb}MB (peak RSS "
              f"{peak:.0f}MB)", flush=True)
        return 0
    except MemoryError:
        print(f"[{mode}] RAM-CAP-ENFORCED: MemoryError under "
              f"RLIMIT_AS={cap_mb}MB", flush=True)
        return EXIT_EXPECTED_OOM


def _spawn(mode: str, args: argparse.Namespace) -> int:
    cmd = [sys.executable, os.path.abspath(__file__), "--child",
           "--mode", mode, "--nodes", str(args.nodes),
           "--cap-mb", str(args.cap_mb), "--out-dir", args.out_dir]
    if args.train:
        cmd.append("--train")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run(cmd, env=env)
    return proc.returncode


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--nodes", type=int, default=2_000_000)
    ap.add_argument("--cap-mb", type=int, default=4096,
                    help="hard RLIMIT_AS for both children")
    ap.add_argument("--out-dir", default=os.path.join(
        REPO, "benchmarks", "artifacts", "streamed", "ram-smoke"))
    ap.add_argument("--train", action="store_true",
                    help="also train a small GNN end-to-end under the cap "
                         "(stream child only needs to survive it)")
    ap.add_argument("--skip-inram", action="store_true",
                    help="only run the stream child (e.g. on hosts where "
                         "the in-RAM control would thrash swap)")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--mode", choices=["stream", "inram"],
                    help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.child:
        return _child(args.mode, args.nodes, args.cap_mb, args.out_dir,
                      args.train)

    print(f"== RAM-budget smoke: n={args.nodes} cap={args.cap_mb}MB ==")
    rc_stream = _spawn("stream", args)
    if rc_stream != 0:
        print(f"FAIL: stream child exited {rc_stream} — the out-of-core "
              f"path does not fit the {args.cap_mb}MB budget")
        return 1
    if not args.skip_inram:
        rc_inram = _spawn("inram", args)
        # Allocation failure under RLIMIT_AS surfaces as a catchable
        # MemoryError (exit 42) in numpy code, but inside XLA's native
        # runtime it aborts on a CHECK failure, so the child dies on a
        # signal (negative returncode). Both are the cap being enforced.
        if rc_inram != EXIT_EXPECTED_OOM and rc_inram >= 0:
            print(f"FAIL: inram child exited {rc_inram} (expected "
                  f"{EXIT_EXPECTED_OOM}) — the cap is not tight enough to "
                  f"rule out in-RAM materialization; lower --cap-mb or "
                  f"raise --nodes")
            return 1
        print("inram control failed under the cap, as it must")
    print("RAM-budget smoke PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
