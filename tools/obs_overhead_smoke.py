#!/usr/bin/env python
"""Disabled-mode obs overhead gate (DESIGN.md §16).

The contract: with tracing disabled (the default), every instrumented
call site costs one function call + one attribute check, and the always-on
metrics cost one locked update each — together under 1% of pipeline wall
time. This smoke *measures* both unit costs with a tight calibration loop,
then multiplies by the number of instrumentation hits an actual traced
pipeline run performs (span count from the tracer, metric mutations from
``MetricsRegistry.total_ops``) and gates the projected disabled-mode
overhead against 1% of the measured disabled-mode pipeline wall.

Projection instead of A/B wall-clock comparison is deliberate: the
pipeline is JIT-dominated and seconds-noisy, so differencing two ~15s
walls cannot resolve a sub-1% effect — multiplying a nanosecond-scale
per-op cost by an exact op count can.

Usage::

    PYTHONPATH=src python tools/obs_overhead_smoke.py
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

CALIBRATION_OPS = 200_000
OVERHEAD_BUDGET = 0.01      # 1% of disabled-mode pipeline wall


def per_op_costs() -> tuple:
    """Measured seconds per disabled span call and per metric mutation."""
    from repro import obs
    obs.reset()     # disabled mode

    t0 = time.perf_counter()
    for _ in range(CALIBRATION_OPS):
        with obs.span("calib.noop", x=1):
            pass
    span_cost = (time.perf_counter() - t0) / CALIBRATION_OPS

    ctr = obs.counter("calib.ops")
    t0 = time.perf_counter()
    for _ in range(CALIBRATION_OPS):
        ctr.inc()
    metric_cost = (time.perf_counter() - t0) / CALIBRATION_OPS
    obs.reset()
    return span_cost, metric_cost


def run_pipeline(traced: bool):
    """One tiny karate pipeline; returns (wall_s, span_count, metric_ops)."""
    from repro import obs
    from repro.pipeline import Pipeline, PipelineConfig
    obs.reset()
    if traced:
        obs.enable()
    cfg = PipelineConfig(dataset="karate", method="leiden_fusion", k=2,
                         mode="local", epochs=3, classifier_epochs=10,
                         collect_hlo=False, cache_dir=None)
    t0 = time.perf_counter()
    Pipeline(cfg).run()
    wall = time.perf_counter() - t0
    spans = obs.tracer().event_count()
    ops = obs.registry().total_ops()
    obs.reset()
    return wall, spans, ops


def main() -> int:
    span_cost, metric_cost = per_op_costs()
    print(f"calibration: {span_cost * 1e9:.0f} ns/disabled-span, "
          f"{metric_cost * 1e9:.0f} ns/metric-op "
          f"({CALIBRATION_OPS} ops each)")

    # traced run: counts every instrumentation hit the pipeline performs
    _, spans, traced_ops = run_pipeline(traced=True)
    # disabled run: the production wall the overhead is measured against
    wall, zero_spans, disabled_ops = run_pipeline(traced=False)
    assert zero_spans == 0, f"disabled mode recorded {zero_spans} spans"

    projected = spans * span_cost + traced_ops * metric_cost
    share = projected / wall
    print(f"pipeline: wall={wall:.2f}s disabled "
          f"({spans} span sites, {traced_ops} metric ops when traced, "
          f"{disabled_ops} metric ops when disabled)")
    print(f"projected disabled-mode overhead: {projected * 1e3:.3f} ms "
          f"= {share * 100:.4f}% of wall (budget {OVERHEAD_BUDGET:.0%})")
    if share >= OVERHEAD_BUDGET:
        print("FAIL: disabled-mode obs overhead exceeds the 1% contract",
              file=sys.stderr)
        return 1
    print("OK: disabled-mode obs overhead within the 1% contract")
    return 0


if __name__ == "__main__":
    sys.exit(main())
