#!/usr/bin/env python3
"""Partitioner registry self-check (CI gate for Partitioner API v2).

For every registered partitioner:

1. instantiate its default config,
2. run it on the Zachary karate club (k=2) and validate the labels
   (shape, dtype, label range) plus any declared capability guarantees
   (connectivity-guaranteed entries must yield single-component,
   isolation-free partitions — via a loose-alpha ``+f`` where the bare
   default would degenerate on a 34-node graph),
3. emit its config fingerprint (and the fingerprint of its ``+f``
   variant).

The default mode runs step 1-3 in TWO fresh subprocesses and fails unless
the emitted fingerprints are byte-identical — the artifact cache keys on
these fingerprints, so any process-dependent ordering/hashing bug would
silently split or poison the cache.

    python tools/registry_selfcheck.py          # the two-process check
    python tools/registry_selfcheck.py --emit   # one process, print lines
"""
from __future__ import annotations

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


def emit() -> int:
    import numpy as np
    from repro.core import (PartitionerSpec, evaluate_partition, karate_club,
                            registered_partitioners)

    g = karate_club()
    k = 2
    failures = []
    lines = []
    for name, entry in registered_partitioners().items():
        entry.config_type()                      # default config instantiates
        spec = PartitionerSpec.parse(name)
        res = spec.partition(g, k, seed=0)
        if res.labels.shape != (g.n,) or res.labels.dtype != np.int64:
            failures.append(f"{name}: bad labels "
                            f"({res.labels.shape}, {res.labels.dtype})")
        if res.labels.min() < 0 or res.labels.max() >= k:
            failures.append(f"{name}: labels outside [0, {k})")
        if entry.capabilities.connectivity_guaranteed:
            rep = evaluate_partition(g, res.labels)
            if rep.max_components != 1 or rep.total_isolated != 0:
                failures.append(f"{name}: claims connectivity but gave "
                                f"components={rep.components_per_part} "
                                f"isolated={rep.total_isolated}")
        lines.append(f"{name} {res.fingerprint}")
        # the +f combinator must compose over every base (loose alpha +
        # over-partitioning: defaults degenerate on a 34-node graph)
        fspec = PartitionerSpec.parse(f"{name}+f(alpha=0.5,base_k=8)")
        frep = evaluate_partition(g, fspec.partition(g, k, seed=0).labels)
        if frep.max_components != 1 or frep.total_isolated != 0:
            failures.append(f"{name}+f: components={frep.components_per_part}"
                            f" isolated={frep.total_isolated}")
        lines.append(f"{name}+f {fspec.fingerprint()}")
    for line in lines:
        print(line)
    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    return 0


def main(argv) -> int:
    if "--emit" in argv:
        return emit()
    env = dict(os.environ)
    existing = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = SRC + (os.pathsep + existing if existing else "")
    runs = []
    for i in range(2):
        out = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--emit"],
            capture_output=True, text=True, env=env, timeout=300)
        if out.returncode != 0:
            print(out.stdout)
            print(out.stderr, file=sys.stderr)
            print(f"registry self-check FAILED (process {i + 1})")
            return 1
        runs.append(out.stdout)
    if runs[0] != runs[1]:
        print("registry self-check FAILED: fingerprints differ between "
              "processes")
        print("--- run 1 ---\n" + runs[0])
        print("--- run 2 ---\n" + runs[1])
        return 1
    n = len(runs[0].strip().splitlines())
    print(runs[0], end="")
    print(f"registry self-check OK ({n} fingerprints stable across "
          f"2 processes)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
